//! Per-region feasibility analysis and integer coefficient enumeration —
//! the paper's Eqns 1–10 made executable.
//!
//! For a region `r` with `N = 2^(n+m-R)` interpolation points and bound
//! slices `L(x) = l_R(r,x)`, `U(x) = u_R(r,x)`:
//!
//! - Eqn 9 feasibility: `forall t, M(t) < m(t)`;
//! - Eqn 10 bounds on `a/2^k`:
//!   `A_lo = max_{t<s} (M(s)-m(t))/(s-t) < a/2^k <
//!    A_hi = min_{t<s} (m(s)-M(t))/(s-t)`;
//! - per integer `a`: `B_lo = max_t (2^k M(t) - a t) < b <
//!   B_hi = min_t (2^k m(t) - a t)` (Eqns 3/4 collapsed onto diagonals);
//! - per `(a, b)`: `C_lo = max_x (2^k L(x) - a x^2 - b x) <= c <
//!   C_hi = min_x (2^k (U(x)+1) - a x^2 - b x)` (Eqn 1).
//!
//! Raising `k` scales every interval by two, so integer feasibility of a
//! region reduces to the real feasibility of Eqns 9/10 plus a minimal-`k`
//! search (paper: "k can be increased until the intervals contain an
//! integer").

use super::envelope::{IntCursor, IntEnvelope, IntLine, RatCursor, RatEnvelope, RatLine};
use super::extrema::{
    diagonal_extrema, diagonal_extrema_fast, max_dd_fracs, max_dd_hull, DiagExtrema, RawFrac,
    SearchStrategy,
};
use crate::rational::Rat;

/// Clamp for the degenerate `N <= 2` regions where `a` (and for `N == 1`
/// also `b`) is unconstrained by the data. The complete space is infinite
/// there; we keep the representatives nearest zero, which are the only ones
/// the width-minimizing decision procedure could ever select.
pub const DEGENERATE_A_CLAMP: i64 = 8;

/// Precomputed §Perf envelopes of the Eqn 3/4 diagonal lines, built once
/// per region and swept for every `(k, a)` afterwards: dividing by `2^k`,
/// `B_lo(a) = 2^k max_t (M(t) - t x)` and
/// `B_hi(a) = 2^k min_t (m(t) - t x)` at `x = a / 2^k`, so both are
/// `k`-independent envelopes of lines in `x`.
#[derive(Clone, Debug)]
pub struct BEnvelopes {
    /// Upper envelope of `y = M(t) - t x` (lines keyed `slope = -t`).
    pub lo: RatEnvelope,
    /// Upper envelope of `y = t x - m(t)` — the negated `B_hi` side
    /// (lines keyed `slope = t`, intercept `-m(t)`).
    pub hi_neg: RatEnvelope,
}

/// Build both Eqn 3/4 envelopes from a region's diagonal extrema. O(N).
pub fn build_b_envelopes(diag: &DiagExtrema) -> BEnvelopes {
    let tmax = diag.big_m.len();
    // Slopes must be fed in ascending order: -t descends in t, +t ascends.
    let lo = RatEnvelope::upper(
        (1..=tmax).rev().map(|t| RatLine { slope: -(t as i64), icept: diag.big_m[t - 1] }),
    );
    let hi_neg = RatEnvelope::upper(
        (1..=tmax).map(|t| RatLine { slope: t as i64, icept: diag.small_m[t - 1].neg() }),
    );
    BEnvelopes { lo, hi_neg }
}

/// Real-interval analysis of one region (everything that does not depend
/// on `k`).
#[derive(Clone, Debug)]
pub struct RegionAnalysis {
    pub r: u64,
    /// Number of interpolation points `N` in the region.
    pub n: usize,
    /// Diagonal extrema (`None` when `N < 2`).
    pub diag: Option<DiagExtrema>,
    /// Eqn 3/4 line envelopes over the diagonals (`None` when `N < 2`).
    pub envs: Option<BEnvelopes>,
    /// Eqn 9: `forall t, M(t) < m(t)`.
    pub chord_ok: bool,
    /// Eqn 10 lower bound on `a/2^k` (`None` = unconstrained below).
    pub a_lo: Option<Rat>,
    /// Eqn 10 upper bound on `a/2^k` (`None` = unconstrained above).
    pub a_hi: Option<Rat>,
    /// Eqns 9 & 10 both hold (a real quadratic exists; integer existence
    /// follows for large enough `k`).
    pub feasible: bool,
    /// Number of divided-difference evaluations spent on the Eqn 10
    /// searches (Claim II.1 instrumentation).
    pub dd_evals: u64,
}

/// Analyze one region from its bound slices.
///
/// `strategy` selects the hull (§Perf default), Claim II.1-pruned or
/// naive implementation of the Eqn 10 searches (all value-identical);
/// `diag` may supply precomputed diagonal extrema (e.g. from the XLA
/// kernel), otherwise they are computed here — with the `i64` fast scan
/// under [`SearchStrategy::Hull`], the reference scan otherwise.
pub fn analyze_region(
    r: u64,
    l: &[i32],
    u: &[i32],
    strategy: SearchStrategy,
    diag: Option<DiagExtrema>,
) -> RegionAnalysis {
    let n = l.len();
    assert_eq!(n, u.len());
    if n < 2 {
        // Single point: any (a, b) with a suitable c works.
        return RegionAnalysis {
            r,
            n,
            diag: None,
            envs: None,
            chord_ok: true,
            a_lo: None,
            a_hi: None,
            feasible: true,
            dd_evals: 0,
        };
    }
    let diag = diag.unwrap_or_else(|| match strategy {
        SearchStrategy::Hull => diagonal_extrema_fast(l, u),
        _ => diagonal_extrema(l, u),
    });
    // Eqn 9: M(t) < m(t) for every diagonal.
    let chord_ok = diag
        .big_m
        .iter()
        .zip(&diag.small_m)
        .all(|(big, small)| big.lt(small));

    // Eqn 10: searches over diagonal index pairs t < s. Note the arrays are
    // indexed by t-1; the divided difference uses the *index difference*
    // s - t, which is preserved by the shift. Gcd-free raw fractions keep
    // the inner loops cheap (§Perf); the hull, pruned and naive searches
    // are value-identical (property-tested in `extrema`).
    let (a_lo, a_hi, dd_evals) = if diag.big_m.len() >= 2 {
        let gm: Vec<RawFrac> = diag.big_m.iter().map(RawFrac::from_rat).collect();
        let gs: Vec<RawFrac> = diag.small_m.iter().map(RawFrac::from_rat).collect();
        let neg = |v: &[RawFrac]| -> Vec<RawFrac> {
            v.iter().map(|f| RawFrac { num: -f.num, den: f.den }).collect()
        };
        // A_lo = max_{t<s} (M(s) - m(t)) / (s - t);
        // A_hi = min_{t<s} (m(s) - M(t)) / (s - t) = -max over negated data.
        let (lo, hi) = match strategy {
            SearchStrategy::Hull => (max_dd_hull(&gm, &gs), max_dd_hull(&neg(&gs), &neg(&gm))),
            _ => {
                let pruned = strategy == SearchStrategy::Pruned;
                (max_dd_fracs(&gm, &gs, pruned), max_dd_fracs(&neg(&gs), &neg(&gm), pruned))
            }
        };
        let evals = lo.map_or(0, |v| v.evals) + hi.map_or(0, |v| v.evals);
        (lo.map(|v| v.value), hi.map(|v| v.value.neg()), evals)
    } else {
        (None, None, 0) // N == 2: a single diagonal, no constraint on a
    };

    let feasible = chord_ok
        && match (&a_lo, &a_hi) {
            (Some(lo), Some(hi)) => lo.lt(hi),
            _ => true,
        };

    let envs = Some(build_b_envelopes(&diag));
    RegionAnalysis { r, n, diag: Some(diag), envs, chord_ok, a_lo, a_hi, feasible, dd_evals }
}

/// One valid `a` with its (inclusive) integer range of valid `b`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AbEntry {
    pub a: i64,
    pub b_lo: i64,
    pub b_hi: i64,
}

/// The complete integer design space of one region at a fixed `k`:
/// every valid `a` paired with its full range of valid `b` (the valid `c`
/// for each `(a, b)` form the contiguous interval given by
/// [`c_interval`], evaluated on demand — storing it per pair would
/// square the memory for no information).
#[derive(Clone, Debug)]
pub struct RegionSpace {
    pub r: u64,
    pub k: u32,
    pub entries: Vec<AbEntry>,
    /// True when `a = 0` is in the space (paper §II: if this holds in all
    /// regions, a piecewise linear implementation suffices).
    pub linear_ok: bool,
}

impl RegionSpace {
    pub fn num_ab_pairs(&self) -> u64 {
        self.entries.iter().map(|e| (e.b_hi - e.b_lo + 1) as u64).sum()
    }
}

/// Integer `a` range at precision `k`: strictly inside
/// `(2^k * a_lo, 2^k * a_hi)`, clamped for degenerate regions.
pub fn a_range_at_k(an: &RegionAnalysis, k: u32) -> (i64, i64) {
    let lo = match &an.a_lo {
        Some(v) => (v.shl(k).floor() + 1) as i64,
        None => -DEGENERATE_A_CLAMP,
    };
    let hi = match &an.a_hi {
        Some(v) => (v.shl(k).ceil() - 1) as i64,
        None => DEGENERATE_A_CLAMP,
    };
    (lo, hi)
}

/// Integer `b` interval for a fixed `(a, k)`: strictly inside
/// `(max_t (2^k M(t) - a t), min_t (2^k m(t) - a t))`.
/// Returns `None` when no integer `b` exists.
///
/// This is the O(N) rescan over every diagonal — retained as the oracle
/// for the envelope path ([`b_range_at_env`], property-tested identical)
/// and for the pre-envelope reference engine.
///
/// Gcd-free scan: `2^k M(t) - a t` as the raw fraction
/// `(num << k) - a t den) / den` — numerators stay < 2^60 for every
/// supported format (num < 2^27, k <= 30, |a| t den < 2^45).
pub fn b_range_at(an: &RegionAnalysis, k: u32, a: i64) -> Option<(i64, i64)> {
    let diag = an.diag.as_ref()?;
    let mut lo: Option<RawFrac> = None;
    let mut hi: Option<RawFrac> = None;
    for (idx, (big, small)) in diag.big_m.iter().zip(&diag.small_m).enumerate() {
        let t = (idx + 1) as i128;
        let at = a as i128 * t;
        let blo = RawFrac { num: (big.num() << k) - at * big.den(), den: big.den() };
        let bhi = RawFrac { num: (small.num() << k) - at * small.den(), den: small.den() };
        lo = Some(match lo {
            Some(v) if blo.lt(&v) => v,
            _ => blo,
        });
        hi = Some(match hi {
            Some(v) if v.lt(&bhi) => v,
            _ => bhi,
        });
    }
    let (lo, hi) = (lo?.to_rat(), hi?.to_rat());
    let b0 = (lo.floor() + 1) as i64;
    let b1 = (hi.ceil() - 1) as i64;
    if b0 <= b1 {
        Some((b0, b1))
    } else {
        None
    }
}

/// The envelope-swept form of [`b_range_at`] (§Perf): instead of
/// rescanning every diagonal, read the two active envelope lines at
/// `x = a / 2^k` and evaluate only those. Cursors must be queried with
/// non-decreasing `a` at a fixed `k`.
///
/// The exact fraction built from the active line is the same
/// `(num << k) - a t den) / den` expression the oracle computes for the
/// maximizing diagonal, so the result is bit-identical.
fn b_interval_from(
    lo_cur: &mut RatCursor<'_>,
    hi_cur: &mut RatCursor<'_>,
    k: u32,
    a: i64,
) -> Option<(i64, i64)> {
    let ll = lo_cur.line_at(a, k);
    let hl = hi_cur.line_at(a, k);
    // Lower side: line slope is -t, intercept M(t).
    let t_lo = (-ll.slope) as i128;
    let m = &ll.icept;
    let lo = RawFrac { num: (m.num() << k) - (a as i128) * t_lo * m.den(), den: m.den() };
    // Upper side: line slope is +t, intercept -m(t).
    let t_hi = hl.slope as i128;
    let s = hl.icept.neg();
    let hi = RawFrac { num: (s.num() << k) - (a as i128) * t_hi * s.den(), den: s.den() };
    let (lo, hi) = (lo.to_rat(), hi.to_rat());
    let b0 = (lo.floor() + 1) as i64;
    let b1 = (hi.ceil() - 1) as i64;
    if b0 <= b1 {
        Some((b0, b1))
    } else {
        None
    }
}

/// One-off envelope query of the `b` interval (fresh cursors; used by the
/// equivalence property tests and spot checks — the enumeration loops
/// keep persistent cursors instead).
pub fn b_range_at_env(an: &RegionAnalysis, k: u32, a: i64) -> Option<(i64, i64)> {
    let envs = an.envs.as_ref()?;
    let mut lo_cur = envs.lo.cursor();
    let mut hi_cur = envs.hi_neg.cursor();
    b_interval_from(&mut lo_cur, &mut hi_cur, k, a)
}

/// Truncated-square / truncated-linear basis terms (paper §III):
/// `T_i(x) = ((x >> i) << i)^2`, `S_j(x) = (x >> j) << j`.
#[inline]
pub fn trunc_sq(x: u64, i: u32) -> i128 {
    let xt = ((x >> i) << i) as i128;
    xt * xt
}

#[inline]
pub fn trunc_lin(x: u64, j: u32) -> i128 {
    ((x >> j) << j) as i128
}

/// Eqn 1 interval of valid `c` for `(a, b, k)` under input truncations
/// `(i, j)`: inclusive `[C_lo, C_hi - 1]`, or `None` if empty.
pub fn c_interval(
    l: &[i32],
    u: &[i32],
    k: u32,
    a: i64,
    b: i64,
    i: u32,
    j: u32,
) -> Option<(i64, i64)> {
    let mut clo = i128::MIN;
    let mut chi = i128::MAX;
    let scale = 1i128 << k;
    for x in 0..l.len() {
        let base = (a as i128) * trunc_sq(x as u64, i) + (b as i128) * trunc_lin(x as u64, j);
        let lo = scale * l[x] as i128 - base;
        let hi = scale * (u[x] as i128 + 1) - base;
        clo = clo.max(lo);
        chi = chi.min(hi);
        if clo >= chi {
            return None;
        }
    }
    debug_assert!(clo >= i64::MIN as i128 && chi - 1 <= i64::MAX as i128);
    Some((clo as i64, (chi - 1) as i64))
}

/// Envelope-backed [`c_interval`] for a fixed `(l, u, k, a, i, j)` across
/// many `b` (§Perf): every interpolation point contributes the integer
/// line `(2^k L(x) - a T_i(x)) - S_j(x) b` to `C_lo` (resp. the negated
/// upper line to `-C_hi`), so one O(N) hull build answers each `b` in
/// O(1) amortized instead of the O(N) rescan. Property-tested identical
/// to [`c_interval`].
#[derive(Clone, Debug)]
pub struct CEnvelope {
    /// Upper envelope of the `C_lo` lines.
    lo: IntEnvelope,
    /// Upper envelope of the negated `C_hi` lines.
    hi_neg: IntEnvelope,
}

impl CEnvelope {
    pub fn build(l: &[i32], u: &[i32], k: u32, a: i64, i: u32, j: u32) -> CEnvelope {
        let n = l.len();
        let scale = 1i128 << k;
        // S_j(x) is non-decreasing in x, so descending x feeds ascending
        // slopes -S_j(x) and ascending x feeds ascending slopes +S_j(x).
        let lo = IntEnvelope::upper((0..n).rev().map(|x| {
            let base = (a as i128) * trunc_sq(x as u64, i);
            IntLine { slope: -trunc_lin(x as u64, j), icept: scale * l[x] as i128 - base }
        }));
        let hi_neg = IntEnvelope::upper((0..n).map(|x| {
            let base = (a as i128) * trunc_sq(x as u64, i);
            IntLine { slope: trunc_lin(x as u64, j), icept: base - scale * (u[x] as i128 + 1) }
        }));
        CEnvelope { lo, hi_neg }
    }

    /// A cursor pair for queries at non-decreasing `b`.
    pub fn cursor(&self) -> CCursor<'_> {
        CCursor { lo: self.lo.cursor(), hi_neg: self.hi_neg.cursor() }
    }

    /// One-off query at an arbitrary `b` (binary search, O(log N)).
    pub fn interval_at(&self, b: i64) -> Option<(i64, i64)> {
        finish_c(self.lo.eval(b), -self.hi_neg.eval(b))
    }
}

/// Monotone query cursor over a [`CEnvelope`].
pub struct CCursor<'a> {
    lo: IntCursor<'a>,
    hi_neg: IntCursor<'a>,
}

impl CCursor<'_> {
    /// Same contract as [`c_interval`]; `b` must be non-decreasing across
    /// calls on one cursor.
    pub fn interval_at(&mut self, b: i64) -> Option<(i64, i64)> {
        finish_c(self.lo.max_at(b), -self.hi_neg.max_at(b))
    }
}

#[inline]
fn finish_c(clo: i128, chi: i128) -> Option<(i64, i64)> {
    if clo >= chi {
        return None;
    }
    debug_assert!(clo >= i64::MIN as i128 && chi - 1 <= i64::MAX as i128);
    Some((clo as i64, (chi - 1) as i64))
}

/// Enumerate the complete integer space of a region at `k`. Returns `None`
/// if no `(a, b)` (with a non-empty `c` interval, which Eqns 3/4 then
/// guarantee) exists at this `k`.
///
/// §Perf: the integer `a` sweep reads the precomputed line envelopes with
/// moving cursors — O(N + |a|) instead of the oracle's O(|a| · N)
/// ([`region_space_at_k_naive`], property-tested identical).
pub fn region_space_at_k(an: &RegionAnalysis, k: u32) -> Option<RegionSpace> {
    if !an.feasible {
        return None;
    }
    if an.n < 2 {
        // Degenerate single-point region: represent the nearest-zero slice
        // of the (infinite) space.
        let entries = vec![AbEntry { a: 0, b_lo: -DEGENERATE_A_CLAMP, b_hi: DEGENERATE_A_CLAMP }];
        return Some(RegionSpace { r: an.r, k, entries, linear_ok: true });
    }
    let envs = an.envs.as_ref().expect("analyzed region with N >= 2 has envelopes");
    let (a0, a1) = a_range_at_k(an, k);
    let mut lo_cur = envs.lo.cursor();
    let mut hi_cur = envs.hi_neg.cursor();
    let mut entries = Vec::new();
    let mut linear_ok = false;
    for a in a0..=a1 {
        if let Some((b0, b1)) = b_interval_from(&mut lo_cur, &mut hi_cur, k, a) {
            if a == 0 {
                linear_ok = true;
            }
            entries.push(AbEntry { a, b_lo: b0, b_hi: b1 });
        }
    }
    if entries.is_empty() {
        None
    } else {
        Some(RegionSpace { r: an.r, k, entries, linear_ok })
    }
}

/// Pre-envelope oracle for [`region_space_at_k`]: rescan every diagonal
/// for every candidate `a`. Kept for the equivalence property tests and
/// the `gen_engine` bench baseline.
pub fn region_space_at_k_naive(an: &RegionAnalysis, k: u32) -> Option<RegionSpace> {
    if !an.feasible {
        return None;
    }
    if an.n < 2 {
        let entries = vec![AbEntry { a: 0, b_lo: -DEGENERATE_A_CLAMP, b_hi: DEGENERATE_A_CLAMP }];
        return Some(RegionSpace { r: an.r, k, entries, linear_ok: true });
    }
    let (a0, a1) = a_range_at_k(an, k);
    let mut entries = Vec::new();
    let mut linear_ok = false;
    for a in a0..=a1 {
        if let Some((b0, b1)) = b_range_at(an, k, a) {
            if a == 0 {
                linear_ok = true;
            }
            entries.push(AbEntry { a, b_lo: b0, b_hi: b1 });
        }
    }
    if entries.is_empty() {
        None
    } else {
        Some(RegionSpace { r: an.r, k, entries, linear_ok })
    }
}

/// Whether `a = 0` is in the region's space at `k` — the
/// [`RegionSpace::linear_ok`] bit answered with one envelope query,
/// without enumerating the space. Used by lazy
/// [`DesignSpace`](crate::designspace::DesignSpace) views for regions
/// that have not been swept (property-tested identical to the
/// materialized bit).
pub fn linear_ok_at_k(an: &RegionAnalysis, k: u32) -> bool {
    if !an.feasible {
        return false;
    }
    if an.n < 2 {
        return true; // degenerate representative always includes a = 0
    }
    let (a0, a1) = a_range_at_k(an, k);
    a0 <= 0 && 0 <= a1 && b_range_at_env(an, k, 0).is_some()
}

/// Number of `(a, b)` pairs the region's space at `k` contains —
/// [`RegionSpace::num_ab_pairs`] computed by the same envelope sweep
/// [`region_space_at_k`] runs, but accumulating widths instead of
/// storing entries: O(1) memory, so size metrics on 20+-bit spaces never
/// materialize anything (property-tested identical).
pub fn num_ab_pairs_at_k(an: &RegionAnalysis, k: u32) -> u64 {
    if !an.feasible {
        return 0;
    }
    if an.n < 2 {
        return (2 * DEGENERATE_A_CLAMP + 1) as u64;
    }
    let envs = an.envs.as_ref().expect("analyzed region with N >= 2 has envelopes");
    let (a0, a1) = a_range_at_k(an, k);
    let mut lo_cur = envs.lo.cursor();
    let mut hi_cur = envs.hi_neg.cursor();
    let mut total = 0u64;
    for a in a0..=a1 {
        if let Some((b0, b1)) = b_interval_from(&mut lo_cur, &mut hi_cur, k, a) {
            total += (b1 - b0 + 1) as u64;
        }
    }
    total
}

/// Existence-only form of [`region_space_at_k`]: does any integer
/// `(a, b)` survive at this `k`? Early-exits on the first witness, so the
/// `k`-search never materializes spaces it will throw away.
pub fn region_feasible_at_k(an: &RegionAnalysis, k: u32) -> bool {
    if !an.feasible {
        return false;
    }
    if an.n < 2 {
        return true;
    }
    let envs = an.envs.as_ref().expect("analyzed region with N >= 2 has envelopes");
    let (a0, a1) = a_range_at_k(an, k);
    let mut lo_cur = envs.lo.cursor();
    let mut hi_cur = envs.hi_neg.cursor();
    (a0..=a1).any(|a| b_interval_from(&mut lo_cur, &mut hi_cur, k, a).is_some())
}

/// Real feasibility of a *degree-1* (forced `a = 0`) polynomial on the
/// region: `max_t M(t) < min_t m(t)`, i.e. one real `b` satisfies every
/// Eqn 3/4 diagonal constraint at once.
///
/// Strictly stronger than [`RegionAnalysis::feasible`] (it implies Eqn 9
/// per-diagonal and `A_lo < 0 < A_hi` in Eqn 10), and `k`-independent:
/// when it holds an integer `b` exists for large enough `k`, when it
/// fails no `k` helps — which is what lets the degree-1 generator
/// classify failures as `InfeasibleRegion` vs `KExhausted` exactly like
/// the quadratic path.
pub fn linear_feasible_real(an: &RegionAnalysis) -> bool {
    let Some(diag) = an.diag.as_ref() else {
        return an.n < 2; // degenerate region: any b works
    };
    let mut max_m = &diag.big_m[0];
    for v in &diag.big_m[1..] {
        if max_m.lt(v) {
            max_m = v;
        }
    }
    let mut min_s = &diag.small_m[0];
    for v in &diag.small_m[1..] {
        if v.lt(min_s) {
            min_s = v;
        }
    }
    max_m.lt(min_s)
}

/// Degree-1 slice of the region's space at `k`: the `a = 0` row of
/// [`region_space_at_k`], or `None` when no integer `b` exists (or the
/// region is not linearly feasible in real arithmetic). The returned
/// entry is bit-identical to the quadratic sweep's `a = 0` entry at the
/// same `k` — both evaluate the same envelope fraction — which is what
/// keeps degree-1 results byte-identical wherever the DSE previously
/// *chose* a linear implementation out of the quadratic space.
pub fn region_space_at_k_deg1(an: &RegionAnalysis, k: u32) -> Option<RegionSpace> {
    if !an.feasible || !linear_feasible_real(an) {
        return None;
    }
    if an.n < 2 {
        let entries = vec![AbEntry { a: 0, b_lo: -DEGENERATE_A_CLAMP, b_hi: DEGENERATE_A_CLAMP }];
        return Some(RegionSpace { r: an.r, k, entries, linear_ok: true });
    }
    let (a0, a1) = a_range_at_k(an, k);
    if !(a0 <= 0 && 0 <= a1) {
        return None;
    }
    let (b_lo, b_hi) = b_range_at_env(an, k, 0)?;
    let entries = vec![AbEntry { a: 0, b_lo, b_hi }];
    Some(RegionSpace { r: an.r, k, entries, linear_ok: true })
}

/// Diagonal-rescan oracle for [`region_space_at_k_deg1`]
/// (property-tested identical).
pub fn region_space_at_k_deg1_naive(an: &RegionAnalysis, k: u32) -> Option<RegionSpace> {
    if !an.feasible || !linear_feasible_real(an) {
        return None;
    }
    if an.n < 2 {
        let entries = vec![AbEntry { a: 0, b_lo: -DEGENERATE_A_CLAMP, b_hi: DEGENERATE_A_CLAMP }];
        return Some(RegionSpace { r: an.r, k, entries, linear_ok: true });
    }
    let (a0, a1) = a_range_at_k(an, k);
    if !(a0 <= 0 && 0 <= a1) {
        return None;
    }
    let (b_lo, b_hi) = b_range_at(an, k, 0)?;
    let entries = vec![AbEntry { a: 0, b_lo, b_hi }];
    Some(RegionSpace { r: an.r, k, entries, linear_ok: true })
}

/// Smallest `k <= max_k` at which the region admits an integer `(0, b, c)`
/// — the degree-1 counterpart of [`min_feasible_k`]. Monotone in `k` for
/// the same doubling reason, so the same exponential-probe search applies
/// with [`linear_ok_at_k`] as the existence predicate.
pub fn min_feasible_k_deg1(an: &RegionAnalysis, max_k: u32) -> Option<u32> {
    if !an.feasible || !linear_feasible_real(an) {
        return None;
    }
    min_monotone(max_k, |k| linear_ok_at_k(an, k))
}

/// Linear-scan oracle for [`min_feasible_k_deg1`].
pub fn min_feasible_k_deg1_naive(an: &RegionAnalysis, max_k: u32) -> Option<u32> {
    if !an.feasible || !linear_feasible_real(an) {
        return None;
    }
    (0..=max_k).find(|&k| region_space_at_k_deg1_naive(an, k).is_some())
}

/// Smallest `v in [0, cap]` with `pred(v)` true, for a monotone predicate
/// (`false.. false true.. true`); `None` when even `cap` fails.
/// Exponential probe upward, then bisection of the bracket — shared by
/// the `k`-search here and the `R`-search in
/// [`crate::designspace::min_lookup_bits_report`].
pub(crate) fn min_monotone(cap: u32, mut pred: impl FnMut(u32) -> bool) -> Option<u32> {
    if pred(0) {
        return Some(0);
    }
    if cap == 0 {
        return None;
    }
    // Exponential probe: lo is always infeasible, hi the first feasible.
    let mut lo = 0u32;
    let mut hi = 1u32;
    loop {
        if hi >= cap {
            if !pred(cap) {
                return None;
            }
            hi = cap;
            break;
        }
        if pred(hi) {
            break;
        }
        lo = hi;
        hi *= 2;
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Smallest `k <= max_k` at which the region admits an integer `(a, b, c)`.
///
/// Feasibility is monotone in `k` — raising `k` scales every real
/// interval by two, so any integer witness `(a, b)` at `k` yields
/// `(2a, 2b)` inside the doubled intervals at `k + 1` (property-tested in
/// `k_escalation_monotone`). The search therefore probes exponentially
/// upward and binary-searches the bracket, using the existence-only
/// predicate: O(log k_min) probes instead of the oracle's linear scan
/// with full enumeration at every step ([`min_feasible_k_naive`]).
pub fn min_feasible_k(an: &RegionAnalysis, max_k: u32) -> Option<u32> {
    if !an.feasible {
        return None;
    }
    min_monotone(max_k, |k| region_feasible_at_k(an, k))
}

/// Pre-envelope oracle for [`min_feasible_k`]: linear `k` scan, fully
/// re-enumerating the space at each step.
pub fn min_feasible_k_naive(an: &RegionAnalysis, max_k: u32) -> Option<u32> {
    if !an.feasible {
        return None;
    }
    (0..=max_k).find(|&k| region_space_at_k_naive(an, k).is_some())
}

/// Exhaustively check Eqn 1 for a concrete `(a, b, c, k)` under
/// truncations `(i, j)` — the definition the whole derivation serves.
pub fn polynomial_valid(
    l: &[i32],
    u: &[i32],
    k: u32,
    a: i64,
    b: i64,
    c: i64,
    i: u32,
    j: u32,
) -> bool {
    let scale = 1i128 << k;
    (0..l.len()).all(|x| {
        let v = (a as i128) * trunc_sq(x as u64, i)
            + (b as i128) * trunc_lin(x as u64, j)
            + c as i128;
        scale * (l[x] as i128) <= v && v < scale * (u[x] as i128 + 1)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{for_each_seed, quadratic_bounds, zigzag_bounds};

    #[test]
    fn envelope_b_range_matches_naive_oracle() {
        for_each_seed(60, |rng| {
            let n = 3 + rng.below(28) as usize;
            let (l, u) =
                if rng.bool() { quadratic_bounds(rng, n) } else { zigzag_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            for k in 0..=6u32 {
                let (a0, a1) = a_range_at_k(&an, k);
                let a1 = a1.min(a0 + 200);
                for a in a0..=a1 {
                    assert_eq!(
                        b_range_at(&an, k, a),
                        b_range_at_env(&an, k, a),
                        "k={k} a={a} l={l:?} u={u:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn envelope_space_equals_naive_space() {
        for_each_seed(60, |rng| {
            let n = 3 + rng.below(28) as usize;
            let (l, u) =
                if rng.bool() { quadratic_bounds(rng, n) } else { zigzag_bounds(rng, n) };
            for strategy in [SearchStrategy::Hull, SearchStrategy::Pruned] {
                let an = analyze_region(0, &l, &u, strategy, None);
                for k in 0..=8u32 {
                    let env = region_space_at_k(&an, k);
                    let naive = region_space_at_k_naive(&an, k);
                    match (env, naive) {
                        (None, None) => {}
                        (Some(e), Some(nv)) => {
                            assert_eq!(e.entries, nv.entries, "k={k} l={l:?} u={u:?}");
                            assert_eq!(e.linear_ok, nv.linear_ok);
                            assert!(region_feasible_at_k(&an, k));
                        }
                        (e, nv) => panic!(
                            "engines disagree at k={k}: env={:?} naive={:?} l={l:?} u={u:?}",
                            e.map(|s| s.entries),
                            nv.map(|s| s.entries)
                        ),
                    }
                }
            }
        });
    }

    #[test]
    fn streamed_metrics_match_materialized_space() {
        // The lazy-view fast paths: linear_ok_at_k and num_ab_pairs_at_k
        // must agree exactly with what region_space_at_k materializes,
        // including the no-space-at-this-k and degenerate cases.
        for_each_seed(60, |rng| {
            let n = 1 + rng.below(30) as usize;
            let (l, u) =
                if rng.bool() { quadratic_bounds(rng, n) } else { zigzag_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            for k in 0..=8u32 {
                match region_space_at_k(&an, k) {
                    Some(sp) => {
                        assert_eq!(
                            linear_ok_at_k(&an, k),
                            sp.linear_ok,
                            "k={k} l={l:?} u={u:?}"
                        );
                        assert_eq!(
                            num_ab_pairs_at_k(&an, k),
                            sp.num_ab_pairs(),
                            "k={k} l={l:?} u={u:?}"
                        );
                    }
                    None => {
                        assert!(!linear_ok_at_k(&an, k), "k={k} l={l:?} u={u:?}");
                        // An empty space has zero pairs; the streamed
                        // count must not invent any.
                        assert_eq!(num_ab_pairs_at_k(&an, k), 0, "k={k} l={l:?} u={u:?}");
                    }
                }
            }
        });
    }

    #[test]
    fn binary_k_search_equals_linear_oracle() {
        for_each_seed(60, |rng| {
            let n = 3 + rng.below(24) as usize;
            let (l, u) =
                if rng.below(3) == 0 { zigzag_bounds(rng, n) } else { quadratic_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            for max_k in [0u32, 1, 3, 10] {
                assert_eq!(
                    min_feasible_k(&an, max_k),
                    min_feasible_k_naive(&an, max_k),
                    "max_k={max_k} l={l:?} u={u:?}"
                );
            }
        });
    }

    #[test]
    fn c_envelope_matches_c_interval_oracle() {
        for_each_seed(60, |rng| {
            let n = 2 + rng.below(28) as usize;
            let (l, u) = quadratic_bounds(rng, n);
            let k = rng.below(6) as u32;
            let a = rng.range_i64(-6, 6);
            let i = rng.below(5) as u32;
            let j = rng.below(4) as u32;
            let env = CEnvelope::build(&l, &u, k, a, i, j);
            let mut cur = env.cursor();
            for b in -90..=90i64 {
                let want = c_interval(&l, &u, k, a, b, i, j);
                assert_eq!(cur.interval_at(b), want, "cursor k={k} a={a} i={i} j={j} b={b}");
                assert_eq!(env.interval_at(b), want, "eval k={k} a={a} i={i} j={j} b={b}");
            }
        });
    }

    #[test]
    fn quadratic_bounds_are_feasible_and_recover_polynomial() {
        for_each_seed(40, |rng| {
            let n = 4 + rng.below(28) as usize;
            let (l, u) = quadratic_bounds(rng, n);
            let an = analyze_region(0, &l, &u, SearchStrategy::Pruned, None);
            assert!(an.feasible, "constructed-feasible region rejected");
            let k = min_feasible_k(&an, 8).expect("k escalation failed");
            let sp = region_space_at_k(&an, k).unwrap();
            // Every enumerated (a, b) admits a c, and the triple verifies.
            for e in &sp.entries {
                for b in e.b_lo..=e.b_hi {
                    let (c0, c1) =
                        c_interval(&l, &u, k, e.a, b, 0, 0).expect("Eqns 3/4 promised a c");
                    assert!(c0 <= c1);
                    for c in [c0, c1] {
                        assert!(
                            polynomial_valid(&l, &u, k, e.a, b, c, 0, 0),
                            "a={} b={b} c={c} k={k}",
                            e.a
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn enumeration_is_complete_small() {
        // On tiny regions, brute-force all (a,b,c) in a window and check the
        // dictionary contains exactly the valid (a,b) pairs.
        for_each_seed(25, |rng| {
            let n = 4 + rng.below(4) as usize;
            let (l, u) = quadratic_bounds(rng, n);
            let an = analyze_region(0, &l, &u, SearchStrategy::Naive, None);
            if !an.feasible {
                return;
            }
            let k = 0u32;
            let space = region_space_at_k(&an, k);
            let in_space = |a: i64, b: i64| {
                space.as_ref().map_or(false, |s| {
                    s.entries.iter().any(|e| e.a == a && (e.b_lo..=e.b_hi).contains(&b))
                })
            };
            for a in -6..=6i64 {
                for b in -80..=80i64 {
                    let valid = c_interval(&l, &u, k, a, b, 0, 0).is_some();
                    assert_eq!(
                        valid,
                        in_space(a, b),
                        "completeness mismatch at a={a} b={b} l={l:?} u={u:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn deg1_space_matches_naive_and_quadratic_a0_row() {
        for_each_seed(60, |rng| {
            let n = 1 + rng.below(30) as usize;
            let (l, u) =
                if rng.bool() { quadratic_bounds(rng, n) } else { zigzag_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            for k in 0..=8u32 {
                let env = region_space_at_k_deg1(&an, k);
                let naive = region_space_at_k_deg1_naive(&an, k);
                match (&env, &naive) {
                    (None, None) => {}
                    (Some(e), Some(nv)) => {
                        assert_eq!(e.entries, nv.entries, "k={k} l={l:?} u={u:?}");
                        assert!(e.linear_ok && e.entries.len() == 1 && e.entries[0].a == 0);
                    }
                    _ => panic!("deg1 engines disagree at k={k} l={l:?} u={u:?}"),
                }
                // The degree-1 space is exactly the a = 0 row of the
                // quadratic space (both present or both absent).
                let quad_a0 = region_space_at_k(&an, k)
                    .and_then(|s| s.entries.iter().find(|e| e.a == 0).copied());
                assert_eq!(
                    env.map(|s| s.entries[0]),
                    quad_a0,
                    "deg1 vs quadratic a=0 row at k={k} l={l:?} u={u:?}"
                );
            }
        });
    }

    #[test]
    fn deg1_k_search_matches_naive_and_dominates_quadratic() {
        for_each_seed(60, |rng| {
            let n = 3 + rng.below(24) as usize;
            let (l, u) =
                if rng.below(3) == 0 { zigzag_bounds(rng, n) } else { quadratic_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            for max_k in [0u32, 1, 3, 10] {
                let fast = min_feasible_k_deg1(&an, max_k);
                assert_eq!(
                    fast,
                    min_feasible_k_deg1_naive(&an, max_k),
                    "max_k={max_k} l={l:?} u={u:?}"
                );
                // Restricting to a = 0 can only raise the minimal k.
                if let (Some(k1), Some(k2)) = (fast, min_feasible_k(&an, max_k)) {
                    assert!(k1 >= k2, "deg1 k={k1} < quadratic k={k2}");
                }
            }
        });
    }

    #[test]
    fn linear_feasible_real_is_k_independent_existence() {
        // When linear real feasibility holds, some k admits an integer b;
        // when it fails, no k ever does.
        for_each_seed(40, |rng| {
            let n = 2 + rng.below(20) as usize;
            let (l, u) =
                if rng.bool() { quadratic_bounds(rng, n) } else { zigzag_bounds(rng, n) };
            let an = analyze_region(0, &l, &u, SearchStrategy::Hull, None);
            if !an.feasible {
                return;
            }
            let any_k = (0..=30u32).any(|k| linear_ok_at_k(&an, k));
            assert_eq!(linear_feasible_real(&an), any_k, "l={l:?} u={u:?}");
        });
    }

    #[test]
    fn infeasible_when_bounds_too_tight_for_quadratic() {
        // A sharp zig-zag cannot be matched by any quadratic with 0 slack.
        let l: Vec<i32> = vec![0, 10, 0, 10, 0, 10, 0, 10];
        let u: Vec<i32> = l.clone();
        let an = analyze_region(0, &l, &u, SearchStrategy::Pruned, None);
        assert!(!an.feasible);
        assert_eq!(min_feasible_k(&an, 20), None);
    }

    #[test]
    fn k_escalation_monotone() {
        // If a region is feasible at k, it must stay feasible at k+1
        // (intervals scale by 2).
        for_each_seed(20, |rng| {
            let n = 4 + rng.below(12) as usize;
            let (l, u) = quadratic_bounds(rng, n);
            let an = analyze_region(0, &l, &u, SearchStrategy::Pruned, None);
            if !an.feasible {
                return;
            }
            if let Some(k) = min_feasible_k(&an, 10) {
                for k2 in k..=(k + 3).min(10) {
                    assert!(
                        region_space_at_k(&an, k2).is_some(),
                        "feasible at k={k} but not k={k2}"
                    );
                }
            }
        });
    }

    #[test]
    fn degenerate_regions() {
        let an1 = analyze_region(0, &[5], &[6], SearchStrategy::Pruned, None);
        assert!(an1.feasible);
        assert!(region_space_at_k(&an1, 0).is_some());

        let an2 = analyze_region(0, &[5, 7], &[6, 8], SearchStrategy::Pruned, None);
        assert!(an2.feasible);
        let sp = region_space_at_k(&an2, 0).unwrap();
        assert!(sp.linear_ok);
        // a is clamped, not unbounded.
        assert!(sp.entries.iter().all(|e| e.a.abs() <= DEGENERATE_A_CLAMP));
    }

    #[test]
    fn truncation_only_shrinks_c_interval() {
        for_each_seed(20, |rng| {
            let n = 8 + rng.below(24) as usize;
            let (l, u) = quadratic_bounds(rng, n);
            let an = analyze_region(0, &l, &u, SearchStrategy::Pruned, None);
            if !an.feasible {
                return;
            }
            let Some(k) = min_feasible_k(&an, 8) else { return };
            let sp = region_space_at_k(&an, k).unwrap();
            let e = sp.entries[sp.entries.len() / 2];
            let b = (e.b_lo + e.b_hi) / 2;
            let full = c_interval(&l, &u, k, e.a, b, 0, 0);
            for i in 0..4u32 {
                for j in 0..3u32 {
                    if let Some((c0, c1)) = c_interval(&l, &u, k, e.a, b, i, j) {
                        let (f0, f1) = full.unwrap();
                        // Truncated interval need not be nested, but any c
                        // valid under truncation is a genuinely valid design.
                        assert!(polynomial_valid(&l, &u, k, e.a, b, c0, i, j));
                        assert!(polynomial_valid(&l, &u, k, e.a, b, c1, i, j));
                        let _ = (f0, f1);
                    }
                }
            }
        });
    }
}
