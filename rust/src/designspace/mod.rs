//! Complete design-space generation (paper §II).
//!
//! [`generate`] turns a [`BoundTable`] plus a lookup-bit count `R` into a
//! [`DesignSpace`]: for every region, every valid integer `a` with its full
//! interval of valid `b` (and, implicitly via [`region::c_interval`], the
//! interval of valid `c` per pair), at the smallest evaluation-precision
//! surplus `k` that is feasible across **all** regions (the paper keeps `k`
//! constant across regions).
//!
//! # Lazy regions (§Scaling)
//!
//! The space is *addressable* eagerly but *materialized* lazily: [`generate`]
//! runs only the analysis phases (per-region envelopes + the common `k`) and
//! stores no entries. A region's `(a, b)` dictionary is re-swept from its
//! envelopes on first touch through a [`RegionView`] and memoized, so
//!
//! - untouched regions cost nothing — peak memory for a 20-bit `generate`
//!   is the analyses, not the exponentially `k`-amplified entry lists;
//! - repeated visits (the decision procedures sweep regions many times)
//!   pay the sweep once;
//! - size metrics ([`DesignSpace::num_ab_pairs`],
//!   [`DesignSpace::linear_feasible`]) stream over the envelopes without
//!   materializing anything.
//!
//! [`generate_eager`] retains the old all-at-once behaviour (parallel
//! phase 3 over the scheduler) as the oracle the lazy path is
//! property-tested byte-identical against; [`generate_naive`] remains the
//! pre-envelope reference engine.

pub mod envelope;
pub mod extrema;
pub mod region;

// Const-initialized static registry; `OnceLock` has no loom mirror and
// this cache is never loom-modeled.
// lint: sync-ok(const-init OnceLock static in never-modeled code)
use std::sync::OnceLock;

use crate::bounds::BoundTable;
use crate::pool::{run_indexed, CancelToken, Progress};
use extrema::{DiagExtrema, SearchStrategy};
use region::{
    linear_feasible_real, min_feasible_k, min_feasible_k_deg1, min_feasible_k_deg1_naive,
    min_feasible_k_naive, region_space_at_k, region_space_at_k_deg1, region_space_at_k_deg1_naive,
    region_space_at_k_naive, AbEntry, RegionAnalysis, RegionSpace,
};

/// Callback that can supply diagonal extrema for a region's bound slices
/// (e.g. the XLA-offloaded kernel in `runtime::extrema`). Returning `None`
/// falls back to the in-process Rust implementation. Providers are not
/// required to be `Sync` (the PJRT wrapper types are not); generation runs
/// single-threaded whenever a provider is installed.
pub type ExtremaProvider<'a> = dyn Fn(&[i32], &[i32]) -> Option<DiagExtrema> + 'a;

/// Options controlling generation.
#[derive(Clone, Copy, Debug)]
pub struct GenOptions {
    /// The paper's `R`: number of lookup bits / log2 of the region count.
    pub lookup_bits: u32,
    /// Eqn 10 search implementation: the hull engine (default), Claim
    /// II.1-pruned, or naive — all value-identical.
    pub search: SearchStrategy,
    /// Give up if no common `k <= max_k` exists.
    pub max_k: u32,
    /// Concurrency budget for the per-region analysis (regions are
    /// independent — the paper's "parallelism" future-work item); work is
    /// scheduled on the process-wide pool ([`crate::pool`]).
    pub threads: usize,
    /// Polynomial degree of the per-region dictionaries: `2` (default)
    /// enumerates the paper's full quadratic `a·x² + b·x + c` space; `1`
    /// restricts generation to the linear `b·x + c` slice (`a = 0`),
    /// choosing the minimal common `k` for *that* space — a distinct
    /// design point from post-hoc selecting `a = 0` out of a quadratic
    /// space, whose `k` the quadratic regions may have inflated.
    pub degree: u32,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            lookup_bits: 6,
            search: SearchStrategy::Hull,
            max_k: 30,
            threads: 1,
            degree: 2,
        }
    }
}

/// Panic on unsupported degrees at the generation entry points, so every
/// downstream match is exhaustive over `{1, 2}`.
fn check_degree(degree: u32) {
    assert!(degree == 1 || degree == 2, "unsupported generation degree {degree} (use 1 or 2)");
}

/// Why generation failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GenError {
    /// Some region violates Eqn 9/10: no real quadratic exists. Use more
    /// lookup bits.
    InfeasibleRegion { r: u64 },
    /// Real-feasible but no integer design within `max_k`.
    KExhausted { r: u64, max_k: u32 },
    /// The run's [`CancelToken`](crate::pool::CancelToken) was triggered:
    /// generation stopped cooperatively between region sweeps. Not a
    /// property of the workload — retrying without cancellation may
    /// succeed.
    Cancelled,
}

impl std::fmt::Display for GenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GenError::InfeasibleRegion { r } => write!(
                f,
                "region {r} admits no quadratic (Eqn 9/10 infeasible); increase lookup bits"
            ),
            GenError::KExhausted { r, max_k } => {
                write!(f, "region {r} has no integer design for any k <= {max_k}")
            }
            GenError::Cancelled => write!(f, "generation cancelled"),
        }
    }
}

impl std::error::Error for GenError {}

/// The complete design space at fixed `(R, k)` — the paper's "nested
/// dictionary of valid polynomial coefficients".
///
/// Regions are stored **lazily**: only the per-region analyses (envelopes
/// and feasibility intervals, already computed during generation) plus
/// the common `k` are kept. Entries are re-swept on demand through
/// [`DesignSpace::region_view`] and memoized per region. Spaces loaded
/// from the disk cache are fully materialized up front (their analyses
/// are not stored) — both representations answer every query
/// identically.
#[derive(Clone, Debug)]
pub struct DesignSpace {
    pub func: String,
    pub accuracy: String,
    /// Total stored input bits `n+m`.
    pub in_bits: u32,
    /// Stored output bits `q`.
    pub out_bits: u32,
    /// Lookup bits `R`.
    pub lookup_bits: u32,
    /// Common evaluation-precision surplus `k`.
    pub k: u32,
    /// Polynomial degree of the region dictionaries (1 or 2); lazy
    /// re-sweeps must enumerate the same slice generation proved feasible.
    pub degree: u32,
    /// Per-region real analyses (the lazy backing store; empty for
    /// cache-loaded spaces, whose regions are pre-materialized).
    pub analyses: Vec<RegionAnalysis>,
    /// Total divided-difference evaluations (Claim II.1 instrumentation).
    pub dd_evals: u64,
    /// Memoized per-region spaces; a cell fills on first touch.
    pub(crate) cells: Vec<OnceLock<RegionSpace>>,
}

/// Lazy, memoizing handle on one region of a [`DesignSpace`]. The first
/// call that needs the entries re-sweeps them from the stored envelopes
/// at the common `k` and caches the result; queries that do not need the
/// entry list ([`RegionView::linear_ok`], [`RegionView::num_ab_pairs`])
/// stream over the envelopes instead of materializing.
#[derive(Clone, Copy)]
pub struct RegionView<'a> {
    ds: &'a DesignSpace,
    r: usize,
}

impl<'a> RegionView<'a> {
    /// Region index `r`.
    pub fn r(&self) -> u64 {
        self.r as u64
    }

    /// Whether this region's entries have already been swept (memoized).
    pub fn is_materialized(&self) -> bool {
        self.ds.cells[self.r].get().is_some()
    }

    /// The materialized region space (swept on first call, then cached).
    pub fn space(&self) -> &'a RegionSpace {
        self.ds.cells[self.r].get_or_init(|| self.ds.sweep_region(self.r))
    }

    /// The complete `(a, b)` dictionary of this region (materializing).
    pub fn entries(&self) -> &'a [AbEntry] {
        &self.space().entries
    }

    /// `a = 0` is in this region's space (answered from the envelopes
    /// when the region has not been materialized).
    pub fn linear_ok(&self) -> bool {
        match self.ds.cells[self.r].get() {
            Some(sp) => sp.linear_ok,
            None => region::linear_ok_at_k(&self.ds.analyses[self.r], self.ds.k),
        }
    }

    /// Number of `(a, b)` pairs in this region (streamed from the
    /// envelopes when the region has not been materialized).
    pub fn num_ab_pairs(&self) -> u64 {
        match self.ds.cells[self.r].get() {
            Some(sp) => sp.num_ab_pairs(),
            None if self.ds.degree == 1 => {
                // Degree-1: one a = 0 row; its b width is the whole count.
                region_space_at_k_deg1(&self.ds.analyses[self.r], self.ds.k)
                    .map_or(0, |sp| sp.num_ab_pairs())
            }
            None => region::num_ab_pairs_at_k(&self.ds.analyses[self.r], self.ds.k),
        }
    }
}

impl DesignSpace {
    /// Interpolation bits per region.
    pub fn x_bits(&self) -> u32 {
        self.in_bits - self.lookup_bits
    }

    /// Points per region.
    pub fn region_len(&self) -> usize {
        1usize << self.x_bits()
    }

    /// Number of regions `2^R`.
    pub fn num_regions(&self) -> usize {
        self.cells.len()
    }

    /// Lazy view of region `r`.
    pub fn region_view(&self, r: usize) -> RegionView<'_> {
        assert!(r < self.cells.len(), "region {r} out of range");
        RegionView { ds: self, r }
    }

    /// Iterate all regions as lazy views, in region order.
    pub fn region_views(&self) -> impl ExactSizeIterator<Item = RegionView<'_>> + '_ {
        (0..self.cells.len()).map(move |r| RegionView { ds: self, r })
    }

    /// Paper §II: a piecewise *linear* approximation suffices iff `a = 0`
    /// is valid in every region. Answered from the envelopes — no region
    /// is materialized by this query.
    pub fn linear_feasible(&self) -> bool {
        self.region_views().all(|v| v.linear_ok())
    }

    /// Total number of `(a, b)` pairs across all regions (design-space
    /// size metric used in reports). Streamed — O(1) extra memory even
    /// for 20+-bit spaces.
    pub fn num_ab_pairs(&self) -> u64 {
        self.region_views().map(|v| v.num_ab_pairs()).sum()
    }

    /// Sweep every unmaterialized region now (phase 3 of the eager
    /// engine), across up to `threads` workers of the process-wide
    /// scheduler. Memoized regions are kept as-is.
    pub fn materialize(&self, threads: usize) {
        let done = self.materialize_ctrl(threads, None);
        debug_assert!(done, "uncancellable materialize reported a cancel");
    }

    /// [`DesignSpace::materialize`] with a cooperative cancel checkpoint
    /// between region sweeps. Returns `false` when the token fired
    /// before every region was swept; already-swept regions stay
    /// memoized (harmless — they are correct, merely early), so the
    /// space remains usable if the caller decides to continue anyway.
    pub fn materialize_ctrl(&self, threads: usize, cancel: Option<&CancelToken>) -> bool {
        let fresh = run_indexed(self.num_regions(), threads, |i| {
            if cancel.is_some_and(|c| c.is_cancelled()) || self.cells[i].get().is_some() {
                return None;
            }
            Some(self.sweep_region(i))
        });
        for (cell, sp) in self.cells.iter().zip(fresh) {
            if let Some(sp) = sp {
                let _ = cell.set(sp);
            }
        }
        !cancel.is_some_and(|c| c.is_cancelled())
    }

    fn sweep_region(&self, i: usize) -> RegionSpace {
        let an = &self.analyses[i];
        let sp = match self.degree {
            1 => region_space_at_k_deg1(an, self.k),
            _ => region_space_at_k(an, self.k),
        };
        sp.unwrap_or_else(|| panic!("region {} lost feasibility at common k={}", an.r, self.k))
    }

    /// Assemble a fully-materialized space (cache loads, the naive
    /// engine). `analyses` may be empty — every cell is pre-filled, so
    /// the lazy backing store is never consulted.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_materialized(
        func: String,
        accuracy: String,
        in_bits: u32,
        out_bits: u32,
        lookup_bits: u32,
        k: u32,
        degree: u32,
        regions: Vec<RegionSpace>,
        analyses: Vec<RegionAnalysis>,
        dd_evals: u64,
    ) -> DesignSpace {
        let cells = regions
            .into_iter()
            .map(|sp| {
                let cell = OnceLock::new();
                let _ = cell.set(sp);
                cell
            })
            .collect();
        DesignSpace {
            func,
            accuracy,
            in_bits,
            out_bits,
            lookup_bits,
            k,
            degree,
            analyses,
            dd_evals,
            cells,
        }
    }
}

/// One shard's analysis phases (phases 1 + 2 restricted to regions
/// `lo..hi`) — the unit of work [`crate::service`]'s cluster layer
/// distributes to workers. Only `min_k` and `dd_evals` need to cross the
/// wire before the sweep phase; the (large) per-region analyses stay on
/// the worker that computed them.
#[derive(Clone, Debug)]
pub struct ShardAnalysis {
    /// First region index covered (inclusive).
    pub lo: u64,
    /// One past the last region index covered.
    pub hi: u64,
    /// Max over the shard's regions of the per-region minimal feasible
    /// `k` — this shard's contribution to the common `k` (which is the
    /// max over all shards).
    pub min_k: u32,
    /// Divided-difference evaluations spent analyzing this shard.
    pub dd_evals: u64,
    /// Per-region analyses, region `lo` first.
    pub analyses: Vec<RegionAnalysis>,
}

/// Split `0..nregions` into up to `shards` contiguous ascending ranges of
/// near-equal length (the first `nregions % shards` ranges get one extra
/// region). Never returns an empty range: the shard count is clamped to
/// `nregions`.
pub fn shard_ranges(nregions: u64, shards: usize) -> Vec<(u64, u64)> {
    let shards = (shards.max(1) as u64).min(nregions.max(1));
    let base = nregions / shards;
    let extra = nregions % shards;
    let mut out = Vec::with_capacity(shards as usize);
    let mut lo = 0u64;
    for i in 0..shards {
        let len = base + u64::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Analyze regions `lo..hi` of the `2^R` range: per-region envelopes plus
/// this shard's max-of-minimal-`k`, exactly as the single-node engine
/// computes them. The ascending feasibility loop mirrors
/// [`generate`]'s, so the first failing region of the shard wins — a
/// coordinator that takes the error of the *failed shard with the
/// smallest `lo`* reproduces the single-node error verbatim.
pub fn analyze_shard(
    bt: &BoundTable,
    opts: &GenOptions,
    lo: u64,
    hi: u64,
    cancel: Option<&CancelToken>,
) -> Result<ShardAnalysis, GenError> {
    assert!(opts.lookup_bits <= bt.in_bits);
    let nregions = 1u64 << opts.lookup_bits;
    assert!(lo < hi && hi <= nregions, "shard {lo}..{hi} out of range for R={}", opts.lookup_bits);
    let cancelled = || cancel.is_some_and(|c| c.is_cancelled());
    let analyses: Option<Vec<RegionAnalysis>> =
        run_indexed((hi - lo) as usize, opts.threads, |i| -> Option<RegionAnalysis> {
            if cancelled() {
                return None;
            }
            let r = lo + i as u64;
            let (l, u) = bt.region(opts.lookup_bits, r);
            Some(region::analyze_region(r, l, u, opts.search, None))
        })
        .into_iter()
        .collect();
    let analyses = analyses.ok_or(GenError::Cancelled)?;
    if cancelled() {
        return Err(GenError::Cancelled);
    }
    let min_k = common_k_of(&analyses, opts)?;
    let dd_evals = analyses.iter().map(|a| a.dd_evals).sum();
    Ok(ShardAnalysis { lo, hi, min_k, dd_evals, analyses })
}

/// Phase 2 shared by every engine and shard: the common `k` (max over
/// regions of the per-region minimum) at the requested degree, with the
/// *first* failing region reported — `InfeasibleRegion` when no real
/// polynomial of that degree exists, `KExhausted` when only `max_k` is
/// in the way.
fn common_k_of(analyses: &[RegionAnalysis], opts: &GenOptions) -> Result<u32, GenError> {
    check_degree(opts.degree);
    let mut k = 0u32;
    for an in analyses {
        if !an.feasible || (opts.degree == 1 && !linear_feasible_real(an)) {
            return Err(GenError::InfeasibleRegion { r: an.r });
        }
        let kr = match opts.degree {
            1 => min_feasible_k_deg1(an, opts.max_k),
            _ => min_feasible_k(an, opts.max_k),
        };
        match kr {
            Some(kr) => k = k.max(kr),
            None => return Err(GenError::KExhausted { r: an.r, max_k: opts.max_k }),
        }
    }
    Ok(k)
}

/// Phase 3 for one shard: sweep every region's `(a, b)` dictionary at the
/// cluster-wide common `k` (which must be `>= self.min_k` — the
/// coordinator computes it as the max over shards), at the same `degree`
/// the shard was analyzed at.
pub fn sweep_shard(sa: &ShardAnalysis, k: u32, degree: u32) -> Vec<RegionSpace> {
    assert!(k >= sa.min_k, "sweep at k={k} below shard minimum {}", sa.min_k);
    check_degree(degree);
    sa.analyses
        .iter()
        .map(|an| {
            let sp = match degree {
                1 => region_space_at_k_deg1(an, k),
                _ => region_space_at_k(an, k),
            };
            sp.unwrap_or_else(|| panic!("region {} lost feasibility at common k={k}", an.r))
        })
        .collect()
}

/// Assemble a [`DesignSpace`] from shard-swept regions, concatenated in
/// region order. Validates full coverage (every region exactly once,
/// ascending, at the common `k`) and pre-fills every cell — the same
/// fully-materialized representation cache loads use, which answers
/// every query identically to a lazily generated space.
pub fn merge_shard_spaces(
    bt: &BoundTable,
    opts: &GenOptions,
    k: u32,
    regions: Vec<RegionSpace>,
    dd_evals: u64,
) -> DesignSpace {
    let nregions = 1u64 << opts.lookup_bits;
    assert_eq!(regions.len() as u64, nregions, "merged shards must cover every region");
    for (i, sp) in regions.iter().enumerate() {
        assert_eq!(sp.r, i as u64, "merged shard regions out of order at slot {i}");
        assert_eq!(sp.k, k, "region {} swept at k={} instead of the common {k}", sp.r, sp.k);
    }
    DesignSpace::from_materialized(
        bt.func.clone(),
        bt.accuracy.clone(),
        bt.in_bits,
        bt.out_bits,
        opts.lookup_bits,
        k,
        opts.degree,
        regions,
        Vec::new(),
        dd_evals,
    )
}

/// Generate the complete design space for `R = opts.lookup_bits`,
/// **lazily**: only the per-region analyses and the common `k` are
/// computed; entries are swept on demand through [`RegionView`]s.
pub fn generate(bt: &BoundTable, opts: &GenOptions) -> Result<DesignSpace, GenError> {
    generate_with(bt, opts, None)
}

/// [`generate`] with an optional external diagonal-extrema provider.
pub fn generate_with(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
) -> Result<DesignSpace, GenError> {
    generate_inner(bt, opts, provider, None, None)
}

/// [`generate`] with cooperative cancellation and progress reporting —
/// the entry point [`crate::service`] jobs run on. The cancel token is
/// polled before each region's analysis (a cancelled run returns
/// [`GenError::Cancelled`] without sweeping the remaining regions);
/// `progress` ticks once per analyzed region after a
/// [`Progress::begin`]`(num_regions)`.
pub fn generate_ctrl(
    bt: &BoundTable,
    opts: &GenOptions,
    cancel: Option<&CancelToken>,
    progress: Option<&Progress>,
) -> Result<DesignSpace, GenError> {
    if let Some(p) = progress {
        p.begin(1usize << opts.lookup_bits);
    }
    generate_inner(bt, opts, None, cancel, progress)
}

/// [`generate_ctrl`] minus the [`Progress::begin`]: `ticks` is advanced
/// once per analyzed region against a window the *caller* opened. This
/// lets one progress window span work beyond a single generate call —
/// e.g. a cache probe that `add`s the whole region count on a hit, or a
/// cluster coordinator accounting remote shards as they land.
pub(crate) fn generate_ticks(
    bt: &BoundTable,
    opts: &GenOptions,
    cancel: Option<&CancelToken>,
    ticks: Option<&Progress>,
) -> Result<DesignSpace, GenError> {
    generate_inner(bt, opts, None, cancel, ticks)
}

fn generate_inner(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
    cancel: Option<&CancelToken>,
    progress: Option<&Progress>,
) -> Result<DesignSpace, GenError> {
    assert!(opts.lookup_bits <= bt.in_bits);
    let nregions = 1u64 << opts.lookup_bits;

    // Phases 1 + 2: per-region analysis, then the common k. Phase 3 (the
    // entry sweep) happens per region on first touch: feasibility at the
    // per-region minimal k implies feasibility at the (>=) common k.
    let (analyses, k) = analyze_and_common_k(bt, opts, provider, nregions, cancel, progress)?;

    let dd_evals = analyses.iter().map(|a| a.dd_evals).sum();
    Ok(DesignSpace {
        func: bt.func.clone(),
        accuracy: bt.accuracy.clone(),
        in_bits: bt.in_bits,
        out_bits: bt.out_bits,
        lookup_bits: opts.lookup_bits,
        k,
        degree: opts.degree,
        analyses,
        dd_evals,
        cells: (0..nregions).map(|_| OnceLock::new()).collect(),
    })
}

/// The eager oracle: [`generate`] plus an immediate parallel
/// materialization of every region (the pre-lazy behaviour, kept for the
/// equivalence property tests, paper-runtime reports and benches).
/// Byte-identical to touching every [`RegionView`] of a lazy space.
pub fn generate_eager(bt: &BoundTable, opts: &GenOptions) -> Result<DesignSpace, GenError> {
    generate_eager_with(bt, opts, None)
}

/// [`generate_eager`] with an optional external diagonal-extrema provider.
pub fn generate_eager_with(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
) -> Result<DesignSpace, GenError> {
    let ds = generate_with(bt, opts, provider)?;
    ds.materialize(opts.threads);
    Ok(ds)
}

/// Phases 1 + 2: analyze every region and find the common `k` (the max
/// over regions of the per-region minimum) — everything feasibility
/// depends on, without materializing any region space. Shared by
/// [`generate_with`] and the existence probes of [`min_lookup_bits`].
fn analyze_and_common_k(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
    nregions: u64,
    cancel: Option<&CancelToken>,
    progress: Option<&Progress>,
) -> Result<(Vec<RegionAnalysis>, u32), GenError> {
    let analyses = analyze_all(bt, opts, provider, nregions, cancel, progress)
        .ok_or(GenError::Cancelled)?;
    // A cancel that lands after the last region was analyzed still wins:
    // the caller asked the run to stop, so it must not observe success.
    if cancel.is_some_and(|c| c.is_cancelled()) {
        return Err(GenError::Cancelled);
    }
    let k = common_k_of(&analyses, opts)?;
    Ok((analyses, k))
}

/// Analyze every region; `None` = the cancel token fired and at least
/// one region was skipped (its analysis slot holds a placeholder that
/// must not be used).
fn analyze_all(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
    nregions: u64,
    cancel: Option<&CancelToken>,
    progress: Option<&Progress>,
) -> Option<Vec<RegionAnalysis>> {
    // The cancellation checkpoint (both branches): polled before each
    // region's sweep, so a cancelled run stops within one region's worth
    // of work per executor.
    let cancelled = || cancel.is_some_and(|c| c.is_cancelled());
    if opts.threads <= 1 || nregions <= 1 || provider.is_some() {
        // Sequential (and the only branch that may consult the non-Sync
        // provider — which is why this closure must not cross into
        // `run_indexed`, whose tasks require `Sync` captures).
        let analyze_one = |r: u64| -> Option<RegionAnalysis> {
            if cancelled() {
                return None;
            }
            let (l, u) = bt.region(opts.lookup_bits, r);
            let diag = provider.and_then(|p| p(l, u));
            let an = region::analyze_region(r, l, u, opts.search, diag);
            if let Some(p) = progress {
                p.tick();
            }
            Some(an)
        };
        return (0..nregions).map(analyze_one).collect();
    }

    // Work-stealing over regions on the process-wide scheduler (shared
    // with `pipeline::Batch`): region cost is *not* uniform — Claim II.1
    // pruning and the hull tangent searches fire unevenly — so workers
    // pull from a shared cursor instead of static chunks. Results are
    // indexed, so the output is thread-count independent.
    run_indexed(nregions as usize, opts.threads, |i| -> Option<RegionAnalysis> {
        if cancelled() {
            return None;
        }
        let (l, u) = bt.region(opts.lookup_bits, i as u64);
        let an = region::analyze_region(i as u64, l, u, opts.search, None);
        if let Some(p) = progress {
            p.tick();
        }
        Some(an)
    })
    .into_iter()
    .collect()
}

/// The pre-envelope reference engine, kept verbatim as the oracle: linear
/// `k` scan with full re-enumeration at every step, per-candidate
/// diagonal rescans, sequential phase 3, fully-materialized result.
/// Value-identical to [`generate`] / [`generate_eager`]
/// (property-tested); the `gen_engine` bench measures all engines in one
/// run. `SearchStrategy::Hull` is mapped to the pre-envelope default
/// `Pruned`.
pub fn generate_naive(bt: &BoundTable, opts: &GenOptions) -> Result<DesignSpace, GenError> {
    assert!(opts.lookup_bits <= bt.in_bits);
    check_degree(opts.degree);
    let nregions = 1u64 << opts.lookup_bits;
    let search = match opts.search {
        SearchStrategy::Hull => SearchStrategy::Pruned,
        other => other,
    };
    let opts = GenOptions { search, ..*opts };
    let analyses =
        analyze_all(bt, &opts, None, nregions, None, None).expect("uncancellable run");
    let mut k = 0u32;
    for an in &analyses {
        if !an.feasible || (opts.degree == 1 && !linear_feasible_real(an)) {
            return Err(GenError::InfeasibleRegion { r: an.r });
        }
        let kr = match opts.degree {
            1 => min_feasible_k_deg1_naive(an, opts.max_k),
            _ => min_feasible_k_naive(an, opts.max_k),
        };
        match kr {
            Some(kr) => k = k.max(kr),
            None => return Err(GenError::KExhausted { r: an.r, max_k: opts.max_k }),
        }
    }
    let mut regions = Vec::with_capacity(nregions as usize);
    for an in &analyses {
        let sp = match opts.degree {
            1 => region_space_at_k_deg1_naive(an, k),
            _ => region_space_at_k_naive(an, k),
        };
        let sp =
            sp.unwrap_or_else(|| panic!("region {} lost feasibility at common k={k}", an.r));
        regions.push(sp);
    }
    let dd_evals = analyses.iter().map(|a| a.dd_evals).sum();
    Ok(DesignSpace::from_materialized(
        bt.func.clone(),
        bt.accuracy.clone(),
        bt.in_bits,
        bt.out_bits,
        opts.lookup_bits,
        k,
        opts.degree,
        regions,
        analyses,
        dd_evals,
    ))
}

/// Find the smallest `R` for which the design space is feasible (the
/// paper's "minimum number of regions required").
pub fn min_lookup_bits(bt: &BoundTable, opts: &GenOptions, r_max: u32) -> Option<u32> {
    min_lookup_bits_report(bt, opts, r_max).ok()
}

/// [`min_lookup_bits`] with evidence: on failure, returns the highest
/// `R` actually probed together with its [`GenError`], distinguishing
/// "needs more lookup bits" ([`GenError::InfeasibleRegion`]) from
/// "needs a larger `max_k`" ([`GenError::KExhausted`]) instead of
/// conflating both into `None`.
///
/// Feasibility is monotone in `R` for every spec shipped here (halving a
/// region can only relax its chord and Eqn 10 constraints —
/// `higher_r_never_increases_k` tests the stronger form), so the probe
/// is exponential + binary over `R`, and each probe runs only the
/// analysis phases — no region space is ever materialized just to be
/// discarded. The assumption is **guarded**: the search spot-checks a
/// skipped `R` below its answer, and on a detected violation (a future,
/// e.g. `R`-dependent, accuracy spec) falls back to an exhaustive linear
/// scan — flagged by a debug assertion (ROADMAP open item). The
/// spot-check is sampled, not exhaustive (see [`min_monotone_guarded`]):
/// certainty would cost the very linear scan the bisection avoids, and
/// every spec shipped today is provably monotone.
pub fn min_lookup_bits_report(
    bt: &BoundTable,
    opts: &GenOptions,
    r_max: u32,
) -> Result<u32, (u32, GenError)> {
    let cap = r_max.min(bt.in_bits);
    let mut last_err: Option<(u32, GenError)> = None;
    let found = min_monotone_guarded(cap, |r| {
        let o = GenOptions { lookup_bits: r, ..*opts };
        match analyze_and_common_k(bt, &o, None, 1u64 << r, None, None) {
            Ok(_) => true,
            Err(e) => {
                // Keep the error from the highest R probed — the most
                // informative one under monotone feasibility.
                if last_err.as_ref().map_or(true, |(pr, _)| r > *pr) {
                    last_err = Some((r, e));
                }
                false
            }
        }
    });
    match found {
        Some((r, monotone_ok)) => {
            debug_assert!(
                monotone_ok,
                "feasibility is not monotone in R for {} ({}); the bisected \
                 lookup-bit search fell back to a linear scan",
                bt.func, bt.accuracy
            );
            Ok(r)
        }
        None => Err(last_err.expect("infeasible probes recorded an error")),
    }
}

/// [`region::min_monotone`] plus a monotonicity spot-check: after the
/// bisection answers `found`, re-probe the largest `R < found` the
/// search *skipped* (the bracket endpoints were all probed infeasible —
/// only skipped interior points can hide a violation). If that probe is
/// feasible, the predicate is not monotone and the search result is
/// untrustworthy: fall back to an exhaustive ascending scan, which needs
/// no assumption. Returns `(minimum, monotone_ok)`.
///
/// This is a *sampled* guard, chosen to keep the O(log) probe count: a
/// non-monotone dip at a different skipped point (or below an
/// infeasible-at-`cap` answer of `None`) escapes detection. Probing
/// every skipped point would re-add exactly the small-`R` probes — the
/// expensive ones — that the exponential+binary scheme exists to skip.
fn min_monotone_guarded(cap: u32, mut pred: impl FnMut(u32) -> bool) -> Option<(u32, bool)> {
    let mut probed: Vec<u32> = Vec::new();
    let found = region::min_monotone(cap, |r| {
        probed.push(r);
        pred(r)
    })?;
    if let Some(rc) = (0..found).rev().find(|r| !probed.contains(r)) {
        if pred(rc) {
            let true_min = (0..=cap).find(|&r| pred(r)).expect("pred(rc) held");
            return Some((true_min, false));
        }
    }
    Some((found, true))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};

    fn table(name: &str, bits: u32) -> BoundTable {
        BoundTable::build(builtin(name, bits).unwrap().as_ref(), AccuracySpec::Ulp(1))
    }

    fn assert_spaces_identical(a: &DesignSpace, b: &DesignSpace, label: &str) {
        assert_eq!(a.k, b.k, "{label}: k differs");
        assert_eq!(a.num_regions(), b.num_regions(), "{label}: region count");
        for (ra, rb) in a.region_views().zip(b.region_views()) {
            assert_eq!(ra.entries(), rb.entries(), "{label} region {}", ra.r());
            assert_eq!(ra.space().linear_ok, rb.space().linear_ok, "{label} region {}", ra.r());
        }
    }

    #[test]
    fn recip8_generates_and_verifies() {
        let bt = table("recip", 8);
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() })
            .expect("recip 8-bit R=4 should be feasible");
        assert_eq!(ds.num_regions(), 16);
        // Spot-verify: every region's first and last (a,b) admit a valid c.
        for rv in ds.region_views() {
            let sp = rv.space();
            let (l, u) = bt.region(4, sp.r);
            for e in [sp.entries.first().unwrap(), sp.entries.last().unwrap()] {
                for b in [e.b_lo, e.b_hi] {
                    let (c0, _) = region::c_interval(l, u, ds.k, e.a, b, 0, 0)
                        .expect("enumerated pair lost its c");
                    assert!(region::polynomial_valid(l, u, ds.k, e.a, b, c0, 0, 0));
                }
            }
        }
    }

    #[test]
    fn lazy_views_match_eager_oracle() {
        // The tentpole invariant, spot form (the broad property grid
        // lives in tests/pipeline_properties.rs): lazy RegionView entries
        // are byte-identical to generate_eager's, and the streamed
        // metrics match the materialized ones.
        let mut checked = 0;
        for (name, bits, r) in [("recip", 8u32, 4u32), ("log2", 8, 3), ("sqrt", 8, 4)] {
            let bt = table(name, bits);
            let opts = GenOptions { lookup_bits: r, ..Default::default() };
            let Ok(lazy) = generate(&bt, &opts) else { continue };
            let eager = generate_eager(&bt, &opts).unwrap();
            checked += 1;
            // Streamed metrics answer without materializing.
            let pairs = lazy.num_ab_pairs();
            let linear = lazy.linear_feasible();
            assert!(
                lazy.region_views().all(|v| !v.is_materialized()),
                "{name}: metric queries must not materialize regions"
            );
            assert_eq!(pairs, eager.num_ab_pairs(), "{name}: pair count");
            assert_eq!(linear, eager.linear_feasible(), "{name}: linear bit");
            assert_spaces_identical(&lazy, &eager, name);
            // After the comparison every region is memoized; metrics now
            // answer from the materialized spaces — same values.
            assert!(lazy.region_views().all(|v| v.is_materialized()));
            assert_eq!(lazy.num_ab_pairs(), pairs);
            assert_eq!(lazy.linear_feasible(), linear);
        }
        assert!(checked >= 2, "too few feasible spot cases: {checked}");
    }

    #[test]
    fn region_views_memoize() {
        let bt = table("exp2", 8);
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() }).unwrap();
        let rv = ds.region_view(3);
        assert!(!rv.is_materialized());
        let first = rv.space() as *const RegionSpace;
        assert!(rv.is_materialized());
        // The memoized space is returned by pointer identity — no resweep.
        assert!(std::ptr::eq(first, ds.region_view(3).space()));
        // Untouched neighbours stay lazy.
        assert!(!ds.region_view(2).is_materialized());
    }

    #[test]
    fn naive_and_pruned_agree_end_to_end() {
        let bt = table("log2", 8);
        let a = generate(
            &bt,
            &GenOptions { lookup_bits: 3, search: SearchStrategy::Naive, ..Default::default() },
        )
        .unwrap();
        let b = generate(
            &bt,
            &GenOptions { lookup_bits: 3, search: SearchStrategy::Pruned, ..Default::default() },
        )
        .unwrap();
        assert_spaces_identical(&a, &b, "log2 naive/pruned");
        assert!(b.dd_evals <= a.dd_evals, "pruning increased work");
    }

    #[test]
    fn all_strategies_and_engines_agree_end_to_end() {
        // The acceptance invariant: hull/pruned/naive strategies and the
        // lazy/eager/pre-envelope engines produce byte-identical spaces —
        // common k, every region's entries, and linear_ok.
        for (name, bits, r) in [("recip", 8u32, 4u32), ("log2", 8, 3), ("exp2", 8, 4)] {
            let bt = table(name, bits);
            let reference = generate(
                &bt,
                &GenOptions { lookup_bits: r, search: SearchStrategy::Hull, ..Default::default() },
            )
            .unwrap_or_else(|e| panic!("{name}: {e}"));
            let others = [
                generate(
                    &bt,
                    &GenOptions {
                        lookup_bits: r,
                        search: SearchStrategy::Pruned,
                        ..Default::default()
                    },
                )
                .unwrap(),
                generate_eager(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                    .unwrap(),
                generate_naive(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                    .unwrap(),
            ];
            for other in others {
                assert_spaces_identical(&reference, &other, name);
            }
        }
    }

    #[test]
    fn degree2_explicit_matches_default() {
        // `degree: 2` is the default — spelling it out must not change a
        // byte of the space (the pre-degree-knob behaviour).
        for (name, bits, r) in [("recip", 8u32, 4u32), ("log2", 8, 3)] {
            let bt = table(name, bits);
            let default =
                generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
            assert_eq!(default.degree, 2);
            let explicit =
                generate(&bt, &GenOptions { lookup_bits: r, degree: 2, ..Default::default() })
                    .unwrap();
            assert_spaces_identical(&default, &explicit, name);
        }
    }

    #[test]
    fn degree1_engines_agree_and_entries_are_linear() {
        let mut checked = 0;
        for (name, bits) in [("recip", 8u32), ("log2", 8), ("tanh", 8), ("sigmoid", 8)] {
            let bt = table(name, bits);
            // Smallest R whose linear space exists (R = in_bits is a
            // guaranteed terminal: single-point regions are degenerate).
            let Some(r) = (0..=bits).find(|&r| {
                generate(&bt, &GenOptions { lookup_bits: r, degree: 1, ..Default::default() })
                    .is_ok()
            }) else {
                continue;
            };
            let opts = GenOptions { lookup_bits: r, degree: 1, ..Default::default() };
            let lazy = generate(&bt, &opts).unwrap();
            checked += 1;
            assert_eq!(lazy.degree, 1);
            // Streamed metrics answer without materializing, and a
            // degree-1 space is linear-feasible by construction.
            let pairs = lazy.num_ab_pairs();
            assert!(lazy.region_views().all(|v| !v.is_materialized()));
            assert!(lazy.linear_feasible(), "{name}: degree-1 space must be linear-feasible");
            // Every region's dictionary is exactly one a = 0 row.
            for rv in lazy.region_views() {
                let sp = rv.space();
                assert_eq!(sp.entries.len(), 1, "{name} region {}", rv.r());
                assert_eq!(sp.entries[0].a, 0, "{name} region {}", rv.r());
                assert!(sp.linear_ok);
            }
            assert_eq!(lazy.num_ab_pairs(), pairs, "{name}: streamed vs materialized");
            // The pre-envelope oracle agrees byte-for-byte.
            let naive = generate_naive(&bt, &opts).unwrap();
            assert_spaces_identical(&lazy, &naive, name);
            // The linear slice can only need at least the quadratic k.
            if let Ok(quad) = generate(&bt, &GenOptions { degree: 2, ..opts }) {
                assert!(lazy.k >= quad.k, "{name}: deg1 k {} < quad k {}", lazy.k, quad.k);
            }
        }
        assert!(checked >= 3, "too few feasible degree-1 cases: {checked}");
    }

    #[test]
    fn degree1_sharded_merge_matches_single_node() {
        let bt = table("sigmoid", 8);
        let r = (0..=8u32)
            .find(|&r| {
                generate(&bt, &GenOptions { lookup_bits: r, degree: 1, ..Default::default() })
                    .is_ok()
            })
            .expect("sigmoid 8-bit degree-1 must be feasible at some R");
        let opts = GenOptions { lookup_bits: r, degree: 1, ..Default::default() };
        let oracle = generate_eager(&bt, &opts).unwrap();
        let n = 1u64 << r;
        for s in [1usize, 2, 3] {
            let shards: Vec<ShardAnalysis> = shard_ranges(n, s)
                .into_iter()
                .map(|(lo, hi)| analyze_shard(&bt, &opts, lo, hi, None).unwrap())
                .collect();
            let k = shards.iter().map(|s| s.min_k).max().unwrap();
            let dd: u64 = shards.iter().map(|s| s.dd_evals).sum();
            let regions: Vec<RegionSpace> =
                shards.iter().flat_map(|s| sweep_shard(s, k, opts.degree)).collect();
            let merged = merge_shard_spaces(&bt, &opts, k, regions, dd);
            assert_eq!(merged.degree, 1);
            assert_spaces_identical(&merged, &oracle, &format!("sigmoid deg1 in {s} shards"));
        }
    }

    #[test]
    #[should_panic(expected = "unsupported generation degree")]
    fn degree3_is_rejected() {
        let bt = table("recip", 8);
        let _ = generate(&bt, &GenOptions { lookup_bits: 4, degree: 3, ..Default::default() });
    }

    #[test]
    fn sharded_merge_matches_single_node() {
        // The cluster invariant: analyze shards independently, take the
        // max of the shard min-ks, sweep each shard at that common k,
        // concatenate — byte-identical to the single-node eager oracle,
        // across shard counts (1, 2, 3, 5, one-per-region) and an
        // uneven hand-built boundary split.
        for (name, bits, r) in [("recip", 8u32, 4u32), ("log2", 8, 3), ("exp2", 8, 4)] {
            let bt = table(name, bits);
            let opts = GenOptions { lookup_bits: r, ..Default::default() };
            let oracle = generate_eager(&bt, &opts).unwrap_or_else(|e| panic!("{name}: {e}"));
            let n = 1u64 << r;
            let mut splits: Vec<Vec<(u64, u64)>> =
                [1usize, 2, 3, 5, n as usize].iter().map(|&s| shard_ranges(n, s)).collect();
            if n >= 4 {
                splits.push(vec![(0, 1), (1, n - 2), (n - 2, n)]);
            }
            for ranges in splits {
                assert_eq!(ranges.iter().map(|(l, h)| h - l).sum::<u64>(), n);
                let shards: Vec<ShardAnalysis> = ranges
                    .iter()
                    .map(|&(lo, hi)| analyze_shard(&bt, &opts, lo, hi, None).unwrap())
                    .collect();
                let k = shards.iter().map(|s| s.min_k).max().unwrap();
                let dd: u64 = shards.iter().map(|s| s.dd_evals).sum();
                let regions: Vec<RegionSpace> =
                    shards.iter().flat_map(|s| sweep_shard(s, k, opts.degree)).collect();
                let merged = merge_shard_spaces(&bt, &opts, k, regions, dd);
                let label = format!("{name} in {} shards", ranges.len());
                assert_eq!(merged.dd_evals, oracle.dd_evals, "{label}: dd_evals");
                assert_spaces_identical(&merged, &oracle, &label);
            }
        }
    }

    #[test]
    fn sharded_error_matches_single_node() {
        // Error precedence: the failed shard with the smallest `lo`
        // carries the exact error the single-node ascending loop
        // reports.
        let bt = table("recip", 8);
        let opts = GenOptions { lookup_bits: 4, max_k: 0, ..Default::default() };
        let single = match generate(&bt, &opts) {
            Err(e) => e,
            Ok(_) => return, // k=0 feasible: nothing to compare
        };
        let first_shard_err = shard_ranges(16, 3)
            .into_iter()
            .filter_map(|(lo, hi)| analyze_shard(&bt, &opts, lo, hi, None).err())
            .next()
            .expect("single-node failed, so some shard must fail");
        assert_eq!(first_shard_err, single);

        // A pre-fired token cancels a shard without analyzing it.
        let cancel = CancelToken::new();
        cancel.cancel();
        let opts = GenOptions { lookup_bits: 4, ..Default::default() };
        let err = analyze_shard(&bt, &opts, 0, 4, Some(&cancel));
        assert_eq!(err.unwrap_err(), GenError::Cancelled);
    }

    #[test]
    fn shard_ranges_partition_contiguously() {
        for n in [1u64, 2, 7, 16, 33] {
            for s in [1usize, 2, 3, 5, 64] {
                let ranges = shard_ranges(n, s);
                assert!(!ranges.is_empty());
                assert!(ranges.len() <= s.max(1));
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges.last().unwrap().1, n);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap/overlap in {ranges:?}");
                }
                assert!(ranges.iter().all(|(l, h)| l < h), "empty shard in {ranges:?}");
            }
        }
    }

    #[test]
    fn min_lookup_bits_report_distinguishes_failures() {
        // recip 8-bit with the default max_k fails below the threshold;
        // the report must carry a structured cause, and agree with the
        // plain Option variant.
        let bt = table("recip", 8);
        let opts = GenOptions::default();
        let rmin = min_lookup_bits(&bt, &opts, 8).expect("some R must work");
        assert_eq!(min_lookup_bits_report(&bt, &opts, 8), Ok(rmin));
        if rmin > 0 {
            // Capped below the threshold: must return the error and the
            // R it was observed at (within the probed range), not Ok.
            let (r_err, err) = min_lookup_bits_report(&bt, &opts, rmin - 1)
                .expect_err("below-threshold cap must fail");
            assert!(r_err < rmin);
            match err {
                GenError::InfeasibleRegion { .. } | GenError::KExhausted { .. } => {}
                GenError::Cancelled => panic!("no cancel token in play"),
            }
        }
        // A max_k of 0 normally makes every R's k-search fail: the report
        // must then say KExhausted (needs more k), not merely "no R
        // worked" — and if some R does admit k = 0, the report must have
        // found a working one.
        let tight = GenOptions { max_k: 0, ..opts };
        match min_lookup_bits_report(&bt, &tight, 4) {
            Err((_, GenError::KExhausted { max_k: 0, .. })) => {}
            Err((r, other)) => panic!("expected KExhausted, got {other} at R={r}"),
            Ok(r) => {
                assert!(generate(&bt, &GenOptions { lookup_bits: r, ..tight }).is_ok());
            }
        }
    }

    #[test]
    fn guarded_search_detects_non_monotone_predicates() {
        // Monotone predicate: bisection answer accepted, flag clean.
        assert_eq!(min_monotone_guarded(8, |r| r >= 5), Some((5, true)));
        assert_eq!(min_monotone_guarded(8, |_| true), Some((0, true)));
        assert_eq!(min_monotone_guarded(3, |_| false), None);

        // Non-monotone predicate crafted so the bisection lands on 7
        // (probes 0,1,2,4,7,5,6 — skipping 3) while the true minimum is
        // 3: the guard re-probes the skipped point and falls back to the
        // exhaustive scan.
        let feasible = [false, false, false, true, false, false, false, true];
        let raw = region::min_monotone(7, |r| feasible[r as usize]);
        assert_eq!(raw, Some(7), "bisection alone must miss the true minimum");
        let guarded = min_monotone_guarded(7, |r| feasible[r as usize]);
        assert_eq!(guarded, Some((3, false)), "guard must detect and correct");
    }

    #[test]
    fn cancelled_generation_reports_cancelled() {
        let bt = table("recip", 8);
        let opts = GenOptions { lookup_bits: 4, ..Default::default() };
        // A pre-fired token cancels before any region is swept.
        let cancel = CancelToken::new();
        cancel.cancel();
        let err = generate_ctrl(&bt, &opts, Some(&cancel), None).unwrap_err();
        assert_eq!(err, GenError::Cancelled);

        // An unfired token is invisible: the ctrl path matches the plain
        // engine and the progress counter lands on (regions, regions).
        let fresh = CancelToken::new();
        let progress = Progress::default();
        let ds = generate_ctrl(&bt, &opts, Some(&fresh), Some(&progress)).unwrap();
        let plain = generate(&bt, &opts).unwrap();
        assert_eq!(progress.get(), (16, 16));
        assert_spaces_identical(&ds, &plain, "ctrl vs plain");

        // materialize_ctrl: a fired token aborts (reporting false), an
        // unfired one completes.
        let lazy = generate(&bt, &opts).unwrap();
        assert!(!lazy.materialize_ctrl(2, Some(&cancel)));
        assert!(lazy.materialize_ctrl(2, Some(&fresh)));
        assert!(lazy.region_views().all(|v| v.is_materialized()));
    }

    #[test]
    fn threads_do_not_change_result() {
        let bt = table("exp2", 8);
        let o1 = GenOptions { lookup_bits: 4, threads: 1, ..Default::default() };
        let o4 = GenOptions { lookup_bits: 4, threads: 4, ..Default::default() };
        let a = generate_eager(&bt, &o1).unwrap();
        let b = generate_eager(&bt, &o4).unwrap();
        assert_spaces_identical(&a, &b, "exp2 1t/4t");
    }

    #[test]
    fn too_few_lookup_bits_is_infeasible_or_high_k() {
        // recip over the full [1,2) range with R=0 and 1-ulp bounds has no
        // single quadratic at 8 bits of precision.
        let bt = table("recip", 8);
        let res = generate(&bt, &GenOptions { lookup_bits: 0, ..Default::default() });
        assert!(res.is_err(), "one quadratic for all of 1/x at 8 bits should fail");
    }

    #[test]
    fn min_lookup_bits_finds_threshold() {
        let bt = table("recip", 8);
        let opts = GenOptions::default();
        let rmin = min_lookup_bits(&bt, &opts, 8).expect("some R must work");
        assert!(rmin >= 1);
        // Feasible at rmin, infeasible below.
        assert!(generate(&bt, &GenOptions { lookup_bits: rmin, ..opts }).is_ok());
        if rmin > 0 {
            assert!(generate(&bt, &GenOptions { lookup_bits: rmin - 1, ..opts }).is_err());
        }
    }

    #[test]
    fn higher_r_never_increases_k() {
        let bt = table("log2", 10);
        let mut prev_k = u32::MAX;
        for r in 4..=7u32 {
            let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                .unwrap_or_else(|e| panic!("R={r}: {e}"));
            assert!(ds.k <= prev_k, "k grew from {prev_k} to {} at R={r}", ds.k);
            prev_k = ds.k;
        }
    }
}
