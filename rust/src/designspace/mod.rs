//! Complete design-space generation (paper §II).
//!
//! [`generate`] turns a [`BoundTable`] plus a lookup-bit count `R` into a
//! [`DesignSpace`]: for every region, every valid integer `a` with its full
//! interval of valid `b` (and, implicitly via [`region::c_interval`], the
//! interval of valid `c` per pair), at the smallest evaluation-precision
//! surplus `k` that is feasible across **all** regions (the paper keeps `k`
//! constant across regions).

pub mod extrema;
pub mod region;

use crate::bounds::BoundTable;
use extrema::{DiagExtrema, SearchStrategy};
use region::{min_feasible_k, region_space_at_k, RegionAnalysis, RegionSpace};

/// Callback that can supply diagonal extrema for a region's bound slices
/// (e.g. the XLA-offloaded kernel in `runtime::extrema`). Returning `None`
/// falls back to the in-process Rust implementation. Providers are not
/// required to be `Sync` (the PJRT wrapper types are not); generation runs
/// single-threaded whenever a provider is installed.
pub type ExtremaProvider<'a> = dyn Fn(&[i32], &[i32]) -> Option<DiagExtrema> + 'a;

/// Options controlling generation.
#[derive(Clone, Copy, Debug)]
pub struct GenOptions {
    /// The paper's `R`: number of lookup bits / log2 of the region count.
    pub lookup_bits: u32,
    /// Naive or Claim II.1-pruned Eqn 10 searches.
    pub search: SearchStrategy,
    /// Give up if no common `k <= max_k` exists.
    pub max_k: u32,
    /// Worker threads for the per-region analysis (regions are
    /// independent — the paper's "parallelism" future-work item).
    pub threads: usize,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { lookup_bits: 6, search: SearchStrategy::Pruned, max_k: 30, threads: 1 }
    }
}

/// Why generation failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GenError {
    /// Some region violates Eqn 9/10: no real quadratic exists. Use more
    /// lookup bits.
    InfeasibleRegion { r: u64 },
    /// Real-feasible but no integer design within `max_k`.
    KExhausted { r: u64, max_k: u32 },
}

impl std::fmt::Display for GenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GenError::InfeasibleRegion { r } => write!(
                f,
                "region {r} admits no quadratic (Eqn 9/10 infeasible); increase lookup bits"
            ),
            GenError::KExhausted { r, max_k } => {
                write!(f, "region {r} has no integer design for any k <= {max_k}")
            }
        }
    }
}

impl std::error::Error for GenError {}

/// The complete design space at fixed `(R, k)` — the paper's "nested
/// dictionary of valid polynomial coefficients".
#[derive(Clone, Debug)]
pub struct DesignSpace {
    pub func: String,
    pub accuracy: String,
    /// Total stored input bits `n+m`.
    pub in_bits: u32,
    /// Stored output bits `q`.
    pub out_bits: u32,
    /// Lookup bits `R`.
    pub lookup_bits: u32,
    /// Common evaluation-precision surplus `k`.
    pub k: u32,
    /// One entry per region `r in [0, 2^R)`.
    pub regions: Vec<RegionSpace>,
    /// Per-region real analyses (kept for the DSE and diagnostics).
    pub analyses: Vec<RegionAnalysis>,
    /// Total divided-difference evaluations (Claim II.1 instrumentation).
    pub dd_evals: u64,
}

impl DesignSpace {
    /// Interpolation bits per region.
    pub fn x_bits(&self) -> u32 {
        self.in_bits - self.lookup_bits
    }

    /// Points per region.
    pub fn region_len(&self) -> usize {
        1usize << self.x_bits()
    }

    /// Paper §II: a piecewise *linear* approximation suffices iff `a = 0`
    /// is valid in every region.
    pub fn linear_feasible(&self) -> bool {
        self.regions.iter().all(|r| r.linear_ok)
    }

    /// Total number of `(a, b)` pairs across all regions (design-space
    /// size metric used in reports).
    pub fn num_ab_pairs(&self) -> u64 {
        self.regions.iter().map(|r| r.num_ab_pairs()).sum()
    }
}

/// Generate the complete design space for `R = opts.lookup_bits`.
pub fn generate(bt: &BoundTable, opts: &GenOptions) -> Result<DesignSpace, GenError> {
    generate_with(bt, opts, None)
}

/// [`generate`] with an optional external diagonal-extrema provider.
pub fn generate_with(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
) -> Result<DesignSpace, GenError> {
    assert!(opts.lookup_bits <= bt.in_bits);
    let nregions = 1u64 << opts.lookup_bits;

    // Phase 1: per-region real analysis (embarrassingly parallel).
    let analyses = analyze_all(bt, opts, provider, nregions);

    // Phase 2: common k = max over regions of the per-region minimum.
    let mut k = 0u32;
    for an in &analyses {
        if !an.feasible {
            return Err(GenError::InfeasibleRegion { r: an.r });
        }
        match min_feasible_k(an, opts.max_k) {
            Some(kr) => k = k.max(kr),
            None => return Err(GenError::KExhausted { r: an.r, max_k: opts.max_k }),
        }
    }

    // Phase 3: enumerate every region at the common k. Feasibility at the
    // per-region minimal k implies feasibility at the (>=) common k.
    let mut regions = Vec::with_capacity(nregions as usize);
    for an in &analyses {
        let sp = region_space_at_k(an, k)
            .unwrap_or_else(|| panic!("region {} lost feasibility at common k={k}", an.r));
        regions.push(sp);
    }

    let dd_evals = analyses.iter().map(|a| a.dd_evals).sum();
    Ok(DesignSpace {
        func: bt.func.clone(),
        accuracy: bt.accuracy.clone(),
        in_bits: bt.in_bits,
        out_bits: bt.out_bits,
        lookup_bits: opts.lookup_bits,
        k,
        regions,
        analyses,
        dd_evals,
    })
}

fn analyze_all(
    bt: &BoundTable,
    opts: &GenOptions,
    provider: Option<&ExtremaProvider<'_>>,
    nregions: u64,
) -> Vec<RegionAnalysis> {
    let analyze_one = |r: u64| -> RegionAnalysis {
        let (l, u) = bt.region(opts.lookup_bits, r);
        let diag = provider.and_then(|p| p(l, u));
        region::analyze_region(r, l, u, opts.search, diag)
    };

    if opts.threads <= 1 || nregions <= 1 || provider.is_some() {
        return (0..nregions).map(analyze_one).collect();
    }

    // Static chunking over a scoped thread pool: regions are uniform cost.
    // (No provider here — the sequential branch above handled that case —
    // so the closure we share across threads is Sync.)
    let analyze_sync = |r: u64| -> RegionAnalysis {
        let (l, u) = bt.region(opts.lookup_bits, r);
        region::analyze_region(r, l, u, opts.search, None)
    };
    let threads = opts.threads.min(nregions as usize);
    let mut results: Vec<Option<RegionAnalysis>> = vec![None; nregions as usize];
    let chunk = (nregions as usize).div_ceil(threads);
    std::thread::scope(|scope| {
        for (tid, slot) in results.chunks_mut(chunk).enumerate() {
            let analyze_sync = &analyze_sync;
            scope.spawn(move || {
                let base = tid * chunk;
                for (off, s) in slot.iter_mut().enumerate() {
                    *s = Some(analyze_sync((base + off) as u64));
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker missed a region")).collect()
}

/// Find the smallest `R` for which the design space is feasible (the
/// paper's "minimum number of regions required").
pub fn min_lookup_bits(bt: &BoundTable, opts: &GenOptions, r_max: u32) -> Option<u32> {
    (0..=r_max.min(bt.in_bits)).find(|&r| {
        let o = GenOptions { lookup_bits: r, ..*opts };
        generate(bt, &o).is_ok()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};

    fn table(name: &str, bits: u32) -> BoundTable {
        BoundTable::build(builtin(name, bits).unwrap().as_ref(), AccuracySpec::Ulp(1))
    }

    #[test]
    fn recip8_generates_and_verifies() {
        let bt = table("recip", 8);
        let ds = generate(&bt, &GenOptions { lookup_bits: 4, ..Default::default() })
            .expect("recip 8-bit R=4 should be feasible");
        assert_eq!(ds.regions.len(), 16);
        // Spot-verify: every region's first and last (a,b) admit a valid c.
        for sp in &ds.regions {
            let (l, u) = bt.region(4, sp.r);
            for e in [sp.entries.first().unwrap(), sp.entries.last().unwrap()] {
                for b in [e.b_lo, e.b_hi] {
                    let (c0, _) = region::c_interval(l, u, ds.k, e.a, b, 0, 0)
                        .expect("enumerated pair lost its c");
                    assert!(region::polynomial_valid(l, u, ds.k, e.a, b, c0, 0, 0));
                }
            }
        }
    }

    #[test]
    fn naive_and_pruned_agree_end_to_end() {
        let bt = table("log2", 8);
        let a = generate(
            &bt,
            &GenOptions { lookup_bits: 3, search: SearchStrategy::Naive, ..Default::default() },
        )
        .unwrap();
        let b = generate(
            &bt,
            &GenOptions { lookup_bits: 3, search: SearchStrategy::Pruned, ..Default::default() },
        )
        .unwrap();
        assert_eq!(a.k, b.k);
        for (ra, rb) in a.regions.iter().zip(&b.regions) {
            assert_eq!(ra.entries, rb.entries, "region {}", ra.r);
        }
        assert!(b.dd_evals <= a.dd_evals, "pruning increased work");
    }

    #[test]
    fn threads_do_not_change_result() {
        let bt = table("exp2", 8);
        let o1 = GenOptions { lookup_bits: 4, threads: 1, ..Default::default() };
        let o4 = GenOptions { lookup_bits: 4, threads: 4, ..Default::default() };
        let a = generate(&bt, &o1).unwrap();
        let b = generate(&bt, &o4).unwrap();
        assert_eq!(a.k, b.k);
        for (ra, rb) in a.regions.iter().zip(&b.regions) {
            assert_eq!(ra.entries, rb.entries);
        }
    }

    #[test]
    fn too_few_lookup_bits_is_infeasible_or_high_k() {
        // recip over the full [1,2) range with R=0 and 1-ulp bounds has no
        // single quadratic at 8 bits of precision.
        let bt = table("recip", 8);
        let res = generate(&bt, &GenOptions { lookup_bits: 0, ..Default::default() });
        assert!(res.is_err(), "one quadratic for all of 1/x at 8 bits should fail");
    }

    #[test]
    fn min_lookup_bits_finds_threshold() {
        let bt = table("recip", 8);
        let opts = GenOptions::default();
        let rmin = min_lookup_bits(&bt, &opts, 8).expect("some R must work");
        assert!(rmin >= 1);
        // Feasible at rmin, infeasible below.
        assert!(generate(&bt, &GenOptions { lookup_bits: rmin, ..opts }).is_ok());
        if rmin > 0 {
            assert!(generate(&bt, &GenOptions { lookup_bits: rmin - 1, ..opts }).is_err());
        }
    }

    #[test]
    fn higher_r_never_increases_k() {
        let bt = table("log2", 10);
        let mut prev_k = u32::MAX;
        for r in 4..=7u32 {
            let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() })
                .unwrap_or_else(|e| panic!("R={r}: {e}"));
            assert!(ds.k <= prev_k, "k grew from {prev_k} to {} at R={r}", ds.k);
            prev_k = ds.k;
        }
    }
}
