//! Line envelopes (convex-hull trick) — the §Perf substrate of the
//! generation engine (see DESIGN.md, "§Perf: envelope enumeration").
//!
//! Two families of bounds in the generator are maxima/minima of *lines*,
//! so they can be swept instead of rescanned:
//!
//! - Eqns 3/4 collapsed onto diagonals: `B_lo(a) = max_t (2^k M(t) - a t)`.
//!   Dividing by `2^k`, each diagonal `t` contributes the `k`-independent
//!   line `y = M(t) - t x` queried at `x = a / 2^k`, so the per-`a` scan
//!   over all diagonals is one upper-envelope query ([`RatEnvelope`]).
//! - Eqn 1: `C_lo(b) = max_x (2^k L(x) - a T_i(x) - b S_j(x))`. Each
//!   interpolation point `x` contributes the all-integer line
//!   `y = (2^k L(x) - a T_i(x)) - S_j(x) b` ([`IntEnvelope`]).
//!
//! Envelopes are built once in O(N) from slope-sorted lines, then queried
//! either with a monotone cursor (O(1) amortized over an ascending integer
//! sweep — the `a`/`b` enumeration loops) or by binary search (O(log N)
//! for isolated points). All comparisons are exact: rational intercepts
//! cross-multiply through [`Rat`], integer lines stay in `i128`.
//!
//! Magnitude analysis (documented per call site): intercepts of the
//! Eqn 3/4 lines are diagonal extrema with numerators `< 2^33` and
//! denominators `< 2^24`; breakpoints are differences of two such over a
//! slope gap `< 2^25`, so every cross product stays well inside `i128`.
//! Eqn 1 lines have `|icept| < 2^94` and `|slope| < 2^24` in the worst
//! supported format, leaving the hull-domination products `< 2^119`.
//! Those envelopes are not trusted silently: cross-multiplied comparisons
//! go through [`crate::wide::cmp_i128_products`], which widens to 256-bit
//! magnitudes when a product overflows `i128`, and line evaluation is
//! checked (loud panic rather than a silent wrap).

use crate::rational::Rat;
use crate::wide::cmp_i128_products;
use std::cmp::Ordering;

/// A line `y = icept + slope * x` with an exact rational intercept.
#[derive(Clone, Copy, Debug)]
pub struct RatLine {
    pub slope: i64,
    pub icept: Rat,
}

/// Upper envelope (pointwise max) of [`RatLine`]s.
#[derive(Clone, Debug)]
pub struct RatEnvelope {
    hull: Vec<RatLine>,
}

impl RatEnvelope {
    /// Build from lines with non-decreasing slopes (equal slopes keep the
    /// larger intercept). O(N).
    pub fn upper<I: IntoIterator<Item = RatLine>>(lines: I) -> RatEnvelope {
        let mut hull: Vec<RatLine> = Vec::new();
        for l in lines {
            if let Some(&top) = hull.last() {
                debug_assert!(top.slope <= l.slope, "slopes must be non-decreasing");
                if top.slope == l.slope {
                    if l.icept.le(&top.icept) {
                        continue;
                    }
                    hull.pop();
                }
            }
            while hull.len() >= 2 {
                let a = hull[hull.len() - 2];
                let b = hull[hull.len() - 1];
                // With slopes m_a < m_b < m_l, line b never rises above
                // both neighbours iff its takeover point from a is at or
                // past l's: (q_b - q_l)(m_b - m_a) <= (q_a - q_b)(m_l - m_b).
                let lhs = b.icept.sub(&l.icept).mul(&Rat::int((b.slope - a.slope) as i128));
                let rhs = a.icept.sub(&b.icept).mul(&Rat::int((l.slope - b.slope) as i128));
                if lhs.le(&rhs) {
                    hull.pop();
                } else {
                    break;
                }
            }
            hull.push(l);
        }
        RatEnvelope { hull }
    }

    pub fn is_empty(&self) -> bool {
        self.hull.is_empty()
    }

    /// Breakpoint `x` from which `hull[i + 1]` dominates `hull[i]`.
    fn breakpoint(hull: &[RatLine], i: usize) -> Option<Rat> {
        let a = hull.get(i)?;
        let b = hull.get(i + 1)?;
        Some(a.icept.sub(&b.icept).div(&Rat::int((b.slope - a.slope) as i128)))
    }

    /// A cursor for queries at non-decreasing `x = a / 2^k`.
    pub fn cursor(&self) -> RatCursor<'_> {
        RatCursor { hull: &self.hull, idx: 0, next: Self::breakpoint(&self.hull, 0) }
    }
}

/// Monotone query cursor over a [`RatEnvelope`].
pub struct RatCursor<'a> {
    hull: &'a [RatLine],
    idx: usize,
    /// Breakpoint where `hull[idx + 1]` takes over (cached).
    next: Option<Rat>,
}

impl<'a> RatCursor<'a> {
    /// The envelope's maximizing line at `x = a / 2^k`. Query points must
    /// be non-decreasing across calls on one cursor; at a breakpoint both
    /// adjacent lines are equal-valued and either may be returned.
    pub fn line_at(&mut self, a: i64, k: u32) -> &'a RatLine {
        debug_assert!(k < 127, "RatCursor shift out of range");
        loop {
            // Advance while a / 2^k >= t  <=>  a * t.den >= t.num * 2^k,
            // compared exactly (widens past i128 instead of wrapping).
            let advance = match &self.next {
                Some(t) => {
                    cmp_i128_products(a as i128, t.den(), t.num(), 1i128 << k) != Ordering::Less
                }
                None => false,
            };
            if !advance {
                break;
            }
            self.idx += 1;
            self.next = RatEnvelope::breakpoint(self.hull, self.idx);
        }
        &self.hull[self.idx]
    }
}

/// A line `y = icept + slope * x` over integers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IntLine {
    pub slope: i128,
    pub icept: i128,
}

#[inline]
fn value(l: IntLine, x: i64) -> i128 {
    l.slope
        .checked_mul(x as i128)
        .and_then(|p| l.icept.checked_add(p))
        .expect("IntLine value overflows i128")
}

/// Upper envelope (pointwise max) of [`IntLine`]s.
#[derive(Clone, Debug)]
pub struct IntEnvelope {
    hull: Vec<IntLine>,
}

impl IntEnvelope {
    /// Build from lines with non-decreasing slopes (equal slopes keep the
    /// larger intercept). O(N).
    pub fn upper<I: IntoIterator<Item = IntLine>>(lines: I) -> IntEnvelope {
        let mut hull: Vec<IntLine> = Vec::new();
        for l in lines {
            if let Some(&top) = hull.last() {
                debug_assert!(top.slope <= l.slope, "slopes must be non-decreasing");
                if top.slope == l.slope {
                    if l.icept <= top.icept {
                        continue;
                    }
                    hull.pop();
                }
            }
            while hull.len() >= 2 {
                let a = hull[hull.len() - 2];
                let b = hull[hull.len() - 1];
                // Same takeover-point test as the rational envelope,
                // cross-multiplied exactly (widens past i128 on demand).
                if cmp_i128_products(
                    b.icept - l.icept,
                    b.slope - a.slope,
                    a.icept - b.icept,
                    l.slope - b.slope,
                ) != Ordering::Greater
                {
                    hull.pop();
                } else {
                    break;
                }
            }
            hull.push(l);
        }
        IntEnvelope { hull }
    }

    pub fn is_empty(&self) -> bool {
        self.hull.is_empty()
    }

    /// Envelope (max) value at `x`, by binary search over the hull —
    /// line values at fixed `x` are unimodal in hull order.
    pub fn eval(&self, x: i64) -> i128 {
        let h = &self.hull;
        let (mut lo, mut hi) = (0usize, h.len() - 1);
        while lo < hi {
            let mid = (lo + hi) / 2; // lint: overflow-ok(usize midpoint of in-bounds hull indices)
            if value(h[mid + 1], x) >= value(h[mid], x) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        value(h[lo], x)
    }

    /// A cursor for queries at non-decreasing integer `x`.
    pub fn cursor(&self) -> IntCursor<'_> {
        IntCursor { hull: &self.hull, idx: 0 }
    }
}

/// Monotone query cursor over an [`IntEnvelope`].
pub struct IntCursor<'a> {
    hull: &'a [IntLine],
    idx: usize,
}

impl IntCursor<'_> {
    /// Envelope (max) value at `x`; query points must be non-decreasing
    /// across calls on one cursor.
    pub fn max_at(&mut self, x: i64) -> i128 {
        let h = self.hull;
        while self.idx + 1 < h.len() && value(h[self.idx + 1], x) >= value(h[self.idx], x) {
            self.idx += 1;
        }
        value(h[self.idx], x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::for_each_seed;

    fn brute_max_int(lines: &[IntLine], x: i64) -> i128 {
        lines.iter().map(|&l| value(l, x)).max().unwrap()
    }

    #[test]
    fn int_envelope_matches_bruteforce() {
        for_each_seed(80, |rng| {
            let n = 1 + rng.below(30) as usize;
            let mut lines: Vec<IntLine> = (0..n)
                .map(|_| IntLine {
                    slope: rng.range_i64(-20, 20) as i128,
                    icept: rng.range_i64(-500, 500) as i128,
                })
                .collect();
            lines.sort_by_key(|l| l.slope);
            let env = IntEnvelope::upper(lines.iter().copied());
            let mut cur = env.cursor();
            let mut x = -60i64;
            while x <= 60 {
                let want = brute_max_int(&lines, x);
                assert_eq!(env.eval(x), want, "eval at x={x} lines={lines:?}");
                assert_eq!(cur.max_at(x), want, "cursor at x={x} lines={lines:?}");
                x += 1 + rng.below(4) as i64;
            }
        });
    }

    #[test]
    fn int_envelope_handles_duplicate_slopes_and_collinear() {
        let lines = [
            IntLine { slope: -1, icept: 3 },
            IntLine { slope: -1, icept: 7 },
            IntLine { slope: 0, icept: 5 },
            IntLine { slope: 1, icept: 3 },
            IntLine { slope: 1, icept: 3 },
            IntLine { slope: 2, icept: 1 },
        ];
        let env = IntEnvelope::upper(lines.iter().copied());
        for x in -10i64..=10 {
            assert_eq!(env.eval(x), brute_max_int(&lines, x), "x={x}");
        }
    }

    #[test]
    fn int_envelope_exact_beyond_i128_product_range() {
        // Intercepts of opposite signs near 2^120: the domination cross
        // products need ~2^131 bits, so the build must widen instead of
        // wrapping. Line values at the query points still fit i128.
        let big = 1i128 << 120;
        let lines = [
            IntLine { slope: -(1 << 10), icept: big },
            IntLine { slope: 0, icept: -big },
            IntLine { slope: 1 << 10, icept: big },
        ];
        let env = IntEnvelope::upper(lines.iter().copied());
        let mut cur = env.cursor();
        for x in [-8i64, -1, 0, 1, 8] {
            let want = brute_max_int(&lines, x);
            assert_eq!(env.eval(x), want, "x={x}");
            assert_eq!(cur.max_at(x), want, "x={x}");
        }
    }

    #[test]
    #[should_panic(expected = "IntLine value overflows")]
    fn int_line_value_overflow_is_loud() {
        let env = IntEnvelope::upper([IntLine { slope: i128::MAX, icept: 1 }]);
        let _ = env.eval(2);
    }

    #[test]
    fn rat_cursor_advances_exactly_on_huge_breakpoints() {
        // Breakpoint (2^100+1)/2^65: at k = 27 both advance-test products
        // (a * den and num * 2^k) reach 2^127, so the comparison must
        // widen. The crossover sits at a = 2^62 + 1 exactly.
        let lines = [
            RatLine { slope: 0, icept: Rat::new((1i128 << 100) + 1, 1i128 << 30) },
            RatLine { slope: 1i64 << 35, icept: Rat::ZERO },
        ];
        let env = RatEnvelope::upper(lines.iter().copied());
        let mut cur = env.cursor();
        assert_eq!(cur.line_at(1 << 62, 27).slope, 0);
        assert_eq!(cur.line_at((1 << 62) + 1, 27).slope, 1i64 << 35);
    }

    #[test]
    fn rat_envelope_matches_bruteforce() {
        for_each_seed(80, |rng| {
            let n = 1 + rng.below(20) as usize;
            // Distinct ascending slopes with random rational intercepts.
            let mut slope = rng.range_i64(-30, -10);
            let lines: Vec<RatLine> = (0..n)
                .map(|_| {
                    let num = rng.range_i64(-200, 200) as i128;
                    let den = 1 + rng.below(7) as i128;
                    let l = RatLine { slope, icept: Rat::new(num, den) };
                    slope += 1 + rng.range_i64(0, 3);
                    l
                })
                .collect();
            let env = RatEnvelope::upper(lines.iter().copied());
            let mut cur = env.cursor();
            let k = rng.below(4) as u32;
            let mut a = -40i64;
            while a <= 40 {
                // Value at x = a / 2^k, exactly.
                let at = |l: &RatLine| {
                    l.icept.add(&Rat::new(l.slope as i128 * a as i128, 1i128 << k))
                };
                let want = lines.iter().map(&at).fold(None::<Rat>, |acc, v| {
                    Some(match acc {
                        Some(b) if v.lt(&b) => b,
                        _ => v,
                    })
                });
                let got = at(cur.line_at(a, k));
                assert_eq!(want.unwrap(), got, "a={a} k={k} lines={lines:?}");
                a += 1 + rng.below(3) as i64;
            }
        });
    }
}
