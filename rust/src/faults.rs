//! Deterministic fault injection (compiled out of release builds).
//!
//! Robustness claims are only as good as the failure modes they were
//! tested against, and ad-hoc "kill a worker mid-job" tests cover a
//! handful of interleavings at best. This module is a process-wide
//! registry of *injection sites*: every I/O boundary of the service
//! stack calls [`inject`] with a site name and the set of faults it
//! knows how to express, and an armed [`FaultPlan`] answers from a
//! seeded PRNG schedule — so a chaos test replays the exact same fault
//! interleaving from the same seed, and a failing seed is a one-line
//! reproduction.
//!
//! The whole module is gated on the `fault-injection` cargo feature.
//! Without it every entry point is an inlineable no-op returning
//! `None`/`0`, so production builds carry zero overhead (the bench gate
//! verifies the default build); with it, faults only fire while a plan
//! is armed, so even `--features fault-injection` test binaries run
//! clean outside the chaos suite.
//!
//! ## Injection sites
//!
//! | site                | faults                          | boundary |
//! |---------------------|---------------------------------|----------|
//! | `cluster.call`      | `Drop`, `Delay`, `Refuse`       | every coordinator↔worker HTTP exchange |
//! | `cluster.call.send` | `Corrupt`, `Truncate`           | outbound request body |
//! | `cluster.call.recv` | `Corrupt`, `Truncate`           | inbound response body |
//! | `cluster.heartbeat` | `Drop`                          | worker agent heartbeat (goes stale) |
//! | `http.read`         | `Delay`                         | server-side request read (slow client) |
//! | `http.respond`      | `Disconnect`                    | server-side response write (mid-response hangup) |
//! | `store.log`         | `ShortWrite`, `Corrupt`, `FsyncFail` | `jobs.log` frame append |
//! | `store.result`      | `ShortWrite`, `Corrupt`         | `.pgjr` result save |
//! | `cache.load`        | `Corrupt`, `Truncate`           | `.pgds` design-space cache read |
//! | `runtime.artifact`  | `Corrupt`                       | XLA `.hlo.txt` artifact read |

// The armed-plan registry and fired counter are const-initialized
// statics; loom's constructors are not `const`, and this module is
// never loom-modeled (chaos and loom are separate jobs).
// lint: sync-ok(const-init statics in never-modeled code)
use std::sync::atomic::{AtomicU64, Ordering};
// lint: sync-ok(const-init statics in never-modeled code)
use std::sync::Mutex;

/// One injectable failure mode. Sites pass the subset they can express
/// to [`inject`], which picks among them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Fail the whole operation as if the peer vanished.
    Drop,
    /// Stall briefly before proceeding (see [`small_delay`]).
    Delay,
    /// Answer with a load-shedding refusal (HTTP 503) instead of work.
    Refuse,
    /// Cut the payload short.
    Truncate,
    /// Flip one bit of the payload.
    Corrupt,
    /// Hang up halfway through writing a response.
    Disconnect,
    /// Persist only a prefix of the frame (torn write).
    ShortWrite,
    /// The write lands but the durability sync fails.
    FsyncFail,
}

/// A seeded fault schedule: which sites fire, how often.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Firing probability per injection-site visit, in permille.
    rate_permille: u32,
    /// When set, only sites whose name starts with this prefix fire.
    only: Option<String>,
}

impl FaultPlan {
    /// A plan firing at 10% per site visit, all sites.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rate_permille: 100, only: None }
    }

    /// Set the per-visit firing probability (permille, clamped to 1000).
    pub fn rate(mut self, permille: u32) -> FaultPlan {
        self.rate_permille = permille.min(1000);
        self
    }

    /// Restrict the plan to sites whose name starts with `prefix`
    /// (e.g. `"cluster."` or `"store."`).
    pub fn only(mut self, prefix: &str) -> FaultPlan {
        self.only = Some(prefix.to_string());
        self
    }
}

/// True when the binary was built with the `fault-injection` feature
/// (whether or not a plan is armed).
pub const COMPILED: bool = cfg!(feature = "fault-injection");

/// Every registered injection site, mirroring the table above. This is
/// the source of truth `cargo xtask lint` cross-checks both ways: a
/// `faults::inject` call whose site literal is not listed here fails
/// the lint, and so does a registry entry with no call site. Keep the
/// table, this list, and the call sites in step.
pub const SITES: &[&str] = &[
    "cluster.call",
    "cluster.call.send",
    "cluster.call.recv",
    "cluster.heartbeat",
    "http.read",
    "http.respond",
    "store.log",
    "store.result",
    "cache.load",
    "runtime.artifact",
];

#[cfg(feature = "fault-injection")]
struct Armed {
    plan: FaultPlan,
    rng: u64,
}

#[cfg(feature = "fault-injection")]
static ARMED: Mutex<Option<Armed>> = Mutex::new(None);

/// Total faults fired since the last [`reset_injected`] — a chaos run
/// asserting "the system survived N faults" needs N > 0 to mean
/// anything.
static INJECTED: AtomicU64 = AtomicU64::new(0);

/// The same fire events, exported through the observability registry so
/// a chaos build's `/metrics` shows fault pressure next to the breaker
/// and quarantine counters ([`INJECTED`] stays the resettable
/// test-facing counter; this one is monotone like every metric).
#[cfg(feature = "fault-injection")]
const FIRED: crate::obs::metrics::Counter = crate::obs::metrics::counter("faults.injected");

#[cfg(feature = "fault-injection")]
fn draw(rng: &mut u64) -> u64 {
    // xorshift64*: deterministic, dependency-free, good enough to
    // scatter faults; never zero-locked because arming bias-seeds it.
    let mut x = *rng;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *rng = x;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// Arm `plan` process-wide. Replaces any previously armed plan.
pub fn arm(plan: FaultPlan) {
    #[cfg(feature = "fault-injection")]
    {
        let rng = plan.seed | 1; // never let the xorshift state be 0
        *ARMED.lock().unwrap_or_else(|e| e.into_inner()) = Some(Armed { plan, rng });
    }
    #[cfg(not(feature = "fault-injection"))]
    let _ = plan;
}

/// Disarm: all sites go quiet again.
pub fn disarm() {
    #[cfg(feature = "fault-injection")]
    {
        *ARMED.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// RAII arming: the plan disarms when the guard drops, so a panicking
/// chaos test cannot leave faults armed for the next test.
pub struct ArmedGuard(());

impl Drop for ArmedGuard {
    fn drop(&mut self) {
        disarm();
    }
}

/// [`arm`], returning a guard that disarms on drop.
pub fn arm_guard(plan: FaultPlan) -> ArmedGuard {
    arm(plan);
    ArmedGuard(())
}

/// Arm from `POLYGEN_FAULT_SEED` / `POLYGEN_FAULT_RATE` (permille) /
/// `POLYGEN_FAULT_ONLY` when the feature is compiled in — the manual
/// chaos knob for a `polygen serve` built with `--features
/// fault-injection`. No-op otherwise.
pub fn arm_from_env() {
    #[cfg(feature = "fault-injection")]
    {
        let Some(seed) = std::env::var("POLYGEN_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
        else {
            return;
        };
        let mut plan = FaultPlan::new(seed);
        if let Some(rate) = std::env::var("POLYGEN_FAULT_RATE")
            .ok()
            .and_then(|s| s.trim().parse::<u32>().ok())
        {
            plan = plan.rate(rate);
        }
        if let Ok(prefix) = std::env::var("POLYGEN_FAULT_ONLY") {
            if !prefix.is_empty() {
                plan = plan.only(&prefix);
            }
        }
        eprintln!("polygen: fault injection armed (seed {seed})");
        arm(plan);
    }
}

/// The injection point. Returns the fault `site` must now exhibit, or
/// `None` (the overwhelmingly common answer, and the only one in
/// default builds, where this compiles to a constant).
#[inline]
pub fn inject(site: &'static str, allowed: &[Fault]) -> Option<Fault> {
    #[cfg(feature = "fault-injection")]
    {
        if allowed.is_empty() {
            return None;
        }
        let mut g = ARMED.lock().unwrap_or_else(|e| e.into_inner());
        let armed = g.as_mut()?;
        if let Some(prefix) = &armed.plan.only {
            if !site.starts_with(prefix.as_str()) {
                return None;
            }
        }
        let roll = draw(&mut armed.rng);
        if (roll % 1000) as u32 >= armed.plan.rate_permille {
            return None;
        }
        let pick = draw(&mut armed.rng);
        let fault = allowed[(pick % allowed.len() as u64) as usize];
        INJECTED.fetch_add(1, Ordering::Relaxed);
        FIRED.inc();
        Some(fault)
    }
    #[cfg(not(feature = "fault-injection"))]
    {
        let _ = (site, allowed);
        None
    }
}

/// A deterministic index below `n` from the armed plan's PRNG — sites
/// use it to pick *which* byte to corrupt or truncate at. Returns 0
/// when unarmed (callers only reach this after [`inject`] fired).
pub fn rand_below(n: usize) -> usize {
    #[cfg(feature = "fault-injection")]
    {
        if n == 0 {
            return 0;
        }
        let mut g = ARMED.lock().unwrap_or_else(|e| e.into_inner());
        match g.as_mut() {
            Some(armed) => (draw(&mut armed.rng) % n as u64) as usize,
            None => 0,
        }
    }
    #[cfg(not(feature = "fault-injection"))]
    {
        let _ = n;
        0
    }
}

/// Sleep 1–25 ms (drawn from the plan) — the body of a `Delay` fault.
pub fn small_delay() {
    let ms = 1 + rand_below(25) as u64;
    std::thread::sleep(std::time::Duration::from_millis(ms));
}

/// Faults fired since the last [`reset_injected`].
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Zero the fired-fault counter (start of a chaos round).
pub fn reset_injected() {
    INJECTED.store(0, Ordering::Relaxed);
}

/// Serialize tests that arm the process-global registry. Unit tests run
/// many-at-once in one process and an armed plan is visible to all of
/// them, so every in-crate test that arms must hold this guard for its
/// whole armed span (test-support only, not part of the API).
#[cfg(feature = "fault-injection")]
#[doc(hidden)]
// lint: sync-ok(const-init static guard in never-modeled code)
pub fn test_serial_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// `Mutex` is only used by the armed implementation; keep the import
// warning-free in default builds.
#[cfg(not(feature = "fault-injection"))]
#[allow(unused)]
fn _unused(_: &Mutex<()>) {}

#[cfg(all(test, feature = "fault-injection"))]
mod tests {
    use super::*;

    // The registry is process-global: serialize these tests against
    // each other and against every other in-crate test that arms.
    use super::test_serial_lock as lock;

    #[test]
    fn disarmed_registry_is_silent() {
        let _g = lock();
        disarm();
        reset_injected();
        for _ in 0..100 {
            assert_eq!(inject("cluster.call", &[Fault::Drop]), None);
        }
        assert_eq!(injected(), 0);
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let _g = lock();
        let run = |seed: u64| -> Vec<Option<Fault>> {
            let _armed = arm_guard(FaultPlan::new(seed).rate(300));
            (0..64).map(|_| inject("store.log", &[Fault::Corrupt, Fault::ShortWrite])).collect()
        };
        let a = run(42);
        let b = run(42);
        let c = run(43);
        assert_eq!(a, b, "same seed must replay identically");
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.iter().any(|f| f.is_some()), "rate 300‰ over 64 visits must fire");
        assert!(a.iter().any(|f| f.is_none()), "rate 300‰ must not always fire");
    }

    #[test]
    fn prefix_filter_scopes_sites() {
        let _g = lock();
        let _armed = arm_guard(FaultPlan::new(7).rate(1000).only("store."));
        assert_eq!(inject("cluster.call", &[Fault::Drop]), None);
        assert!(inject("store.log", &[Fault::Corrupt]).is_some());
    }

    #[test]
    fn rate_1000_always_fires_and_counts() {
        let _g = lock();
        let _armed = arm_guard(FaultPlan::new(9).rate(1000));
        reset_injected();
        for _ in 0..10 {
            assert!(inject("http.read", &[Fault::Delay]).is_some());
        }
        assert_eq!(injected(), 10);
        assert!(rand_below(5) < 5);
        assert_eq!(rand_below(0), 0);
    }
}
