//! Unified failure-handling policy for the cluster's network calls.
//!
//! PR 6's cluster hard-coded one 30 s socket timeout and treated any
//! single failed call as a dead worker. This module centralizes the
//! knobs that replace that: a [`Policy`] (per-attempt deadline, bounded
//! retries with jittered exponential backoff), a process-wide
//! [`RetryBudget`] so a coordinator under correlated failure cannot
//! amplify its own load with retry storms, a per-worker
//! [`CircuitBreaker`] that quarantines a flapping worker after K
//! consecutive failed calls and probes it back in after a cooldown, and
//! the [`TokenBucket`] the HTTP front-end uses for per-client request
//! budgets. Everything here is transport-agnostic plain state —
//! `service::cluster` composes it with its HTTP client, which keeps
//! this module unit-testable without sockets. See DESIGN.md §Fault
//! model.

use std::time::{Duration, Instant};

use crate::obs::metrics;
use crate::sync::{plock, Mutex};

const CALLS: metrics::Counter = metrics::counter("net.calls");
const RETRIES: metrics::Counter = metrics::counter("net.retries");
const CALL_FAILURES: metrics::Counter = metrics::counter("net.call_failures");
const CALL_MS: metrics::Histogram = metrics::histogram("net.call_ms");
const BREAKER_OPENED: metrics::Counter = metrics::counter("net.breaker_opened");
const BREAKER_RECLOSED: metrics::Counter = metrics::counter("net.breaker_reclosed");
const BUDGET_LEVEL: metrics::Gauge = metrics::gauge("net.retry_budget_millitokens");

/// Failure-handling knobs for one class of calls. CLI spelling:
/// `--call-timeout SECS --retries N --breaker-threshold K`.
#[derive(Clone, Debug, PartialEq)]
pub struct Policy {
    /// Deadline for a single attempt (connect + read + write).
    pub call_timeout: Duration,
    /// Extra attempts after the first failure (0 = never retry).
    pub retries: u32,
    /// Base backoff before the first retry; doubles per retry, jittered
    /// to 50–100% so synchronized retries spread out.
    pub backoff: Duration,
    /// Consecutive failed calls before a worker's breaker opens.
    pub breaker_threshold: u32,
    /// How long an open breaker rejects calls before letting one probe
    /// through.
    pub breaker_cooldown: Duration,
}

impl Default for Policy {
    fn default() -> Policy {
        Policy {
            call_timeout: Duration::from_secs(10),
            retries: 2,
            backoff: Duration::from_millis(100),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_secs(5),
        }
    }
}

impl Policy {
    /// Run `attempt` under this policy: each attempt gets
    /// `call_timeout`, failures are retried up to `retries` times with
    /// jittered exponential backoff, and every retry must be paid for
    /// from `budget` (when one is supplied). `breaker` is consulted
    /// before the first attempt — an open breaker short-circuits — and
    /// told about the *call's* final outcome (one success or one
    /// failure per `run`, not per attempt, so a call that succeeds on
    /// retry does not advance the breaker).
    pub fn run<T>(
        &self,
        budget: Option<&RetryBudget>,
        breaker: Option<&CircuitBreaker>,
        mut attempt: impl FnMut(Duration) -> Result<T, String>,
    ) -> Result<T, String> {
        CALLS.inc();
        let call_start = Instant::now();
        if let Some(b) = breaker {
            if !b.allow() {
                CALL_FAILURES.inc();
                CALL_MS.observe(call_start.elapsed().as_millis() as u64);
                return Err("circuit open (worker quarantined)".into());
            }
        }
        let mut failures = 0u32;
        loop {
            match attempt(self.call_timeout) {
                Ok(v) => {
                    if let Some(b) = breaker {
                        b.on_success();
                    }
                    if let Some(bu) = budget {
                        bu.deposit(0.1);
                    }
                    CALL_MS.observe(call_start.elapsed().as_millis() as u64);
                    return Ok(v);
                }
                Err(e) => {
                    failures += 1;
                    let can_retry =
                        failures <= self.retries && budget.map_or(true, |b| b.try_spend());
                    if !can_retry {
                        if let Some(b) = breaker {
                            b.on_failure(self.breaker_threshold, self.breaker_cooldown);
                        }
                        CALL_FAILURES.inc();
                        CALL_MS.observe(call_start.elapsed().as_millis() as u64);
                        return Err(e);
                    }
                    RETRIES.inc();
                    std::thread::sleep(jittered_backoff(self.backoff, failures - 1));
                }
            }
        }
    }
}

/// Exponential backoff with 50–100% jitter: `base << attempt`, scaled
/// by a cheap clock-derived factor so a fleet of synchronized retriers
/// decorrelates. Capped at `base << 6`.
pub fn jittered_backoff(base: Duration, attempt: u32) -> Duration {
    let full = base.saturating_mul(1u32 << attempt.min(6));
    let jitter = std::time::SystemTime::UNIX_EPOCH.elapsed().map_or(0, |d| d.subsec_nanos());
    // Map the jitter into [512, 1024) / 1024 ≈ [50%, 100%).
    let scale = 512 + (jitter % 512) as u64;
    Duration::from_nanos((full.as_nanos() as u64).saturating_mul(scale) / 1024)
}

/// A process-wide retry allowance: every retry spends one token, every
/// success drips a fraction back. When correlated failures drain it,
/// calls fail fast instead of multiplying load on whatever is left
/// standing.
pub struct RetryBudget {
    state: Mutex<BudgetState>,
}

struct BudgetState {
    tokens: f64,
    cap: f64,
}

impl RetryBudget {
    /// A budget starting (and capped) at `cap` tokens.
    pub fn new(cap: f64) -> RetryBudget {
        RetryBudget { state: Mutex::new(BudgetState { tokens: cap, cap }) }
    }

    /// Spend one retry token; `false` = budget exhausted, fail fast.
    pub fn try_spend(&self) -> bool {
        let mut s = plock(&self.state);
        let ok = if s.tokens >= 1.0 {
            s.tokens -= 1.0;
            true
        } else {
            false
        };
        BUDGET_LEVEL.set((s.tokens * 1000.0) as u64);
        ok
    }

    /// Return `amount` tokens (successful calls refill the budget).
    pub fn deposit(&self, amount: f64) {
        let mut s = plock(&self.state);
        s.tokens = (s.tokens + amount).min(s.cap);
        BUDGET_LEVEL.set((s.tokens * 1000.0) as u64);
    }

    /// Tokens currently available (observability / tests).
    pub fn available(&self) -> f64 {
        plock(&self.state).tokens
    }
}

/// Where a breaker currently stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Calls flow normally.
    Closed,
    /// Quarantined: calls are rejected until the cooldown elapses.
    Open,
    /// Cooldown elapsed: the next call goes through as a probe.
    HalfOpen,
}

/// A per-peer circuit breaker: after `threshold` *consecutive* failed
/// calls the peer is quarantined for `cooldown`, then a single probe is
/// let through — success closes the breaker, failure re-opens it for
/// another cooldown. Counting whole calls (not attempts) means a peer
/// that recovers within a call's retry budget never trips it.
pub struct CircuitBreaker {
    state: Mutex<BreakerInner>,
}

struct BreakerInner {
    consecutive: u32,
    open_until: Option<Instant>,
}

impl Default for CircuitBreaker {
    fn default() -> CircuitBreaker {
        CircuitBreaker::new()
    }
}

impl CircuitBreaker {
    pub fn new() -> CircuitBreaker {
        CircuitBreaker { state: Mutex::new(BreakerInner { consecutive: 0, open_until: None }) }
    }

    /// May a call proceed right now? (Closed or probe-ready.)
    pub fn allow(&self) -> bool {
        let s = plock(&self.state);
        match s.open_until {
            None => true,
            Some(t) => Instant::now() >= t,
        }
    }

    /// Is the peer quarantined (open, including probe-ready)?
    pub fn is_open(&self) -> bool {
        plock(&self.state).open_until.is_some()
    }

    pub fn state(&self) -> BreakerState {
        let s = plock(&self.state);
        match s.open_until {
            None => BreakerState::Closed,
            Some(t) if Instant::now() >= t => BreakerState::HalfOpen,
            Some(_) => BreakerState::Open,
        }
    }

    /// Record a successful call: the breaker closes fully.
    pub fn on_success(&self) {
        let mut s = plock(&self.state);
        s.consecutive = 0;
        if s.open_until.take().is_some() {
            BREAKER_RECLOSED.inc();
        }
    }

    /// Record a failed call; returns `true` when this failure *newly*
    /// opened the breaker (the caller's cue to log the quarantine). A
    /// failed probe re-arms the cooldown without returning `true`.
    pub fn on_failure(&self, threshold: u32, cooldown: Duration) -> bool {
        let mut s = plock(&self.state);
        s.consecutive = s.consecutive.saturating_add(1);
        if s.consecutive >= threshold.max(1) {
            let newly = s.open_until.is_none();
            s.open_until = Some(Instant::now() + cooldown);
            if newly {
                BREAKER_OPENED.inc();
            }
            newly
        } else {
            false
        }
    }
}

/// A classic token bucket: `rate` tokens/second refill up to `burst`,
/// one token per request. Used per client IP by the HTTP front-end;
/// callers serialize access (the front-end keeps buckets in a mutexed
/// map).
pub struct TokenBucket {
    tokens: f64,
    last: Instant,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    /// A bucket that starts full.
    pub fn new(rate: f64, burst: f64) -> TokenBucket {
        let rate = if rate > 0.0 { rate } else { 1.0 };
        let burst = if burst >= 1.0 { burst } else { 1.0 };
        TokenBucket { tokens: burst, last: Instant::now(), rate, burst }
    }

    /// Take one token. `Err(secs)` = exhausted; retry after `secs`
    /// (≥ 1, suitable for an HTTP `Retry-After` header).
    pub fn try_take(&mut self) -> Result<(), u64> {
        let now = Instant::now();
        self.tokens =
            (self.tokens + now.duration_since(self.last).as_secs_f64() * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(((1.0 - self.tokens) / self.rate).ceil().max(1.0) as u64)
        }
    }

    /// Is the bucket back at capacity? (Idle buckets can be pruned.)
    pub fn is_full(&mut self) -> bool {
        let now = Instant::now();
        self.tokens =
            (self.tokens + now.duration_since(self.last).as_secs_f64() * self.rate).min(self.burst);
        self.last = now;
        self.tokens >= self.burst - 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicU32, Ordering};

    fn fast_policy() -> Policy {
        Policy {
            call_timeout: Duration::from_millis(50),
            retries: 2,
            backoff: Duration::from_millis(1),
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(40),
        }
    }

    #[test]
    fn run_retries_up_to_the_limit_then_surfaces_the_error() {
        let p = fast_policy();
        let calls = AtomicU32::new(0);
        let r: Result<(), String> = p.run(None, None, |timeout| {
            assert_eq!(timeout, p.call_timeout, "attempts get the per-call deadline");
            calls.fetch_add(1, Ordering::Relaxed);
            Err("nope".into())
        });
        assert_eq!(r.unwrap_err(), "nope");
        assert_eq!(calls.load(Ordering::Relaxed), 3, "1 try + 2 retries");

        let calls = AtomicU32::new(0);
        let r = p.run(None, None, |_| {
            if calls.fetch_add(1, Ordering::Relaxed) < 2 {
                Err("flaky".into())
            } else {
                Ok(7u32)
            }
        });
        assert_eq!(r.unwrap(), 7, "success on the last retry wins");
    }

    #[test]
    fn retry_budget_bounds_retry_storms() {
        let p = fast_policy();
        let budget = RetryBudget::new(3.0);
        let mut total_attempts = 0u32;
        for _ in 0..10 {
            let _ = p.run::<()>(Some(&budget), None, |_| {
                total_attempts += 1;
                Err("down".into())
            });
        }
        // 10 first attempts are free; only 3 retries fit the budget.
        assert_eq!(total_attempts, 13);
        // Successes drip tokens back in.
        for _ in 0..10 {
            let _ = p.run(Some(&budget), None, |_| Ok(()));
        }
        assert!(budget.available() >= 1.0);
        let _ = p.run::<()>(Some(&budget), None, |_| {
            total_attempts += 1;
            Err("down".into())
        });
        assert!(total_attempts > 13, "replenished budget allows retries again");
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_and_probes_back_in() {
        let p = fast_policy();
        let b = CircuitBreaker::new();
        assert_eq!(b.state(), BreakerState::Closed);
        for _ in 0..2 {
            let _ = p.run::<()>(None, Some(&b), |_| Err("down".into()));
        }
        assert!(b.is_open(), "threshold 2 consecutive failed calls must open it");
        assert_eq!(b.state(), BreakerState::Open);
        // While open, calls short-circuit without invoking the attempt.
        let r = p.run::<()>(None, Some(&b), |_| panic!("must not be attempted"));
        assert!(r.unwrap_err().contains("circuit open"));
        // After the cooldown a probe goes through; success closes it.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(p.run(None, Some(&b), |_| Ok(1u8)).unwrap(), 1);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_failed_probe_rearms_the_cooldown() {
        let p = fast_policy();
        let b = CircuitBreaker::new();
        assert!(b.on_failure(1, Duration::from_millis(10)), "first open is 'newly'");
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow(), "cooldown elapsed: probe allowed");
        assert!(!b.on_failure(1, Duration::from_millis(200)), "re-open is not 'newly'");
        assert!(!b.allow(), "failed probe re-quarantines");
        // An intervening success always closes fully.
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn a_success_resets_the_consecutive_count() {
        let b = CircuitBreaker::new();
        let cd = Duration::from_secs(5);
        assert!(!b.on_failure(3, cd));
        assert!(!b.on_failure(3, cd));
        b.on_success();
        assert!(!b.on_failure(3, cd), "count restarted after success");
        assert!(!b.is_open());
    }

    #[test]
    fn jittered_backoff_stays_in_range_and_grows() {
        let base = Duration::from_millis(100);
        for attempt in 0..4u32 {
            let full = base * (1 << attempt);
            let d = jittered_backoff(base, attempt);
            assert!(d >= full / 2 && d <= full, "attempt {attempt}: {d:?} vs {full:?}");
        }
        // The shift saturates instead of overflowing.
        let d = jittered_backoff(Duration::from_secs(1), 40);
        assert!(d <= Duration::from_secs(64));
    }

    #[test]
    fn token_bucket_enforces_rate_and_reports_retry_after() {
        let mut tb = TokenBucket::new(10.0, 2.0);
        assert!(tb.try_take().is_ok());
        assert!(tb.try_take().is_ok());
        let wait = tb.try_take().unwrap_err();
        assert!(wait >= 1, "Retry-After must be at least 1s, got {wait}");
        // 10 tokens/s refill: ~150ms buys one back.
        std::thread::sleep(Duration::from_millis(150));
        assert!(tb.try_take().is_ok());
        assert!(!tb.is_full());
        std::thread::sleep(Duration::from_millis(350));
        assert!(tb.is_full(), "idle bucket refills to burst");
    }
}
