//! Gate-level component models — the Design Compiler / TSMC 7 nm stand-in.
//!
//! The paper's Table I / Figs 2–3 are Synopsys DC synthesis results on a
//! TSMC 7 nm library. We cannot run DC, so (DESIGN.md §3) we model each
//! datapath component analytically at the gate-equivalent level and
//! calibrate two global constants (area of a NAND2-equivalent, one FO4
//! delay) to the 7 nm magnitudes the paper reports. The *shape* of every
//! comparison — which architecture is smaller, how area trades against the
//! delay target, where LUT-height crossovers sit — comes out of the
//! structural models, not the calibration.

/// Area of one gate equivalent (NAND2), µm². Calibrated so a 16-bit
/// quadratic interpolator lands in the paper's few-hundred-µm² range.
pub const GE_UM2: f64 = 0.065;
/// One FO4 inverter delay, ns (≈7 ps in a fast 7 nm process).
pub const FO4_NS: f64 = 0.007;

/// Area/delay of one component at maximum drive (minimum delay).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    /// Gate equivalents.
    pub area_ge: f64,
    /// FO4 units on the component's critical path.
    pub delay_fo4: f64,
}

impl Cost {
    pub fn zero() -> Cost {
        Cost::default()
    }

    pub fn area_um2(&self) -> f64 {
        self.area_ge * GE_UM2
    }

    pub fn delay_ns(&self) -> f64 {
        self.delay_fo4 * FO4_NS
    }
}

/// Carry-save reduction depth for `rows` partial-product rows down to 2
/// (Dadda sequence: 2, 3, 4, 6, 9, 13, 19, 28, ...).
pub fn dadda_stages(rows: u32) -> u32 {
    if rows <= 2 {
        return 0;
    }
    let mut h = 2u32;
    let mut stages = 0u32;
    while h < rows {
        h = h * 3 / 2;
        stages += 1;
    }
    stages
}

/// Parallel-prefix (Kogge-Stone-ish) adder of width `w`.
pub fn adder(w: u32) -> Cost {
    if w == 0 {
        return Cost::zero();
    }
    let lg = (w.max(2) as f64).log2();
    Cost {
        // w PG cells + w*log2(w) prefix nodes + w sum XORs.
        area_ge: w as f64 * (2.0 + 1.6 * lg) + w as f64,
        delay_fo4: 2.0 + 1.8 * lg,
    }
}

/// Signed multiplier `w1 x w2` (radix-4 Booth, Dadda tree, final CPA).
pub fn multiplier(w1: u32, w2: u32) -> Cost {
    if w1 == 0 || w2 == 0 {
        return Cost::zero();
    }
    let rows = w1.div_ceil(2) + 1; // Booth radix-4 rows
    let pp_area = rows as f64 * (w2 as f64 + 2.0) * 1.6; // mux-based PP cells
    let csa_area = (rows.saturating_sub(2)) as f64 * (w1 + w2) as f64 * 4.5;
    let cpa = adder(w1 + w2);
    Cost {
        area_ge: pp_area + csa_area + cpa.area_ge,
        delay_fo4: 3.0 /* booth enc+mux */ + dadda_stages(rows) as f64 * 2.2 + cpa.delay_fo4,
    }
}

/// Dedicated squarer of width `w` (folding halves the partial products).
pub fn squarer(w: u32) -> Cost {
    if w == 0 {
        return Cost::zero();
    }
    let rows = (w.div_ceil(2) + 1).max(1);
    let pp_area = 0.5 * w as f64 * (w as f64 + 1.0) * 1.2; // folded AND array
    let csa_area = rows.saturating_sub(2) as f64 * (2 * w) as f64 * 4.0;
    let cpa = adder(2 * w);
    Cost {
        area_ge: pp_area + csa_area + cpa.area_ge,
        delay_fo4: 1.0 + dadda_stages(rows) as f64 * 2.2 + cpa.delay_fo4,
    }
}

/// Synthesized ROM (the coefficient LUT): `2^r_bits` words of `width`
/// bits, implemented as random logic after minimization (how DC treats a
/// `case` table). Empirical logic-compaction factor ~0.35 per bit-cell,
/// shrinking slightly with height as minimization finds shared cubes.
pub fn lut(r_bits: u32, width: u32) -> Cost {
    if width == 0 || r_bits == 0 {
        return Cost::zero();
    }
    let entries = (1u64 << r_bits) as f64;
    let share = 0.38 * (1.0 - 0.018 * r_bits as f64).max(0.55);
    Cost {
        area_ge: entries * width as f64 * share + width as f64 * 2.0,
        delay_fo4: 1.0 + 1.35 * r_bits as f64 + 0.4 * (width.max(2) as f64).log2(),
    }
}

/// 3:2 carry-save compression of `n` operands of width `w`, plus the final
/// carry-propagate adder.
pub fn multi_operand_add(n: u32, w: u32) -> Cost {
    if n <= 1 {
        return Cost::zero();
    }
    let layers = dadda_stages(n);
    let cpa = adder(w);
    Cost {
        area_ge: (n.saturating_sub(2)) as f64 * w as f64 * 4.5 + cpa.area_ge,
        delay_fo4: layers as f64 * 2.2 + cpa.delay_fo4,
    }
}

/// Delay-target sizing model: synthesizing for a tighter delay costs area
/// (gate upsizing, buffering, logic duplication). `effort = d_min / d`
/// in (0, 1]; multiplier grows gently, then steeply as `d -> d_min`.
pub fn sizing_multiplier(d_min_ns: f64, d_target_ns: f64) -> f64 {
    assert!(d_target_ns > 0.0 && d_min_ns > 0.0);
    let e = (d_min_ns / d_target_ns).min(1.0);
    1.0 + 0.9 * e.powi(3) / (1.5 - e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dadda_depths() {
        assert_eq!(dadda_stages(2), 0);
        assert_eq!(dadda_stages(3), 1);
        assert_eq!(dadda_stages(4), 2);
        assert_eq!(dadda_stages(6), 3);
        assert_eq!(dadda_stages(9), 4);
        assert_eq!(dadda_stages(13), 5);
        assert_eq!(dadda_stages(19), 6);
    }

    #[test]
    fn monotone_in_width() {
        for w in 2..30u32 {
            assert!(multiplier(w + 1, w).area_ge > multiplier(w, w - 1).area_ge);
            assert!(adder(w + 1).area_ge > adder(w).area_ge);
            assert!(squarer(w + 1).area_ge > squarer(w).area_ge);
            assert!(lut(8, w + 1).area_ge > lut(8, w).area_ge);
        }
    }

    #[test]
    fn squarer_cheaper_than_multiplier() {
        for w in 4..24u32 {
            assert!(
                squarer(w).area_ge < multiplier(w, w).area_ge,
                "squarer({w}) should beat {w}x{w} multiplier"
            );
        }
    }

    #[test]
    fn lut_scales_with_height() {
        let a6 = lut(6, 30).area_ge;
        let a8 = lut(8, 30).area_ge;
        assert!(a8 > 3.0 * a6, "doubling R twice should ~4x the LUT");
        assert!(lut(8, 30).delay_fo4 > lut(6, 30).delay_fo4);
    }

    #[test]
    fn sizing_curve_shape() {
        let dmin = 0.2;
        let relaxed = sizing_multiplier(dmin, 0.4);
        let tight = sizing_multiplier(dmin, 0.21);
        let at_min = sizing_multiplier(dmin, 0.2);
        assert!(relaxed < tight && tight < at_min);
        assert!(relaxed < 1.4, "relaxed target should be near minimum area");
        assert!(at_min > 2.0 && at_min < 6.0, "min-delay costs a few x area: {at_min}");
    }

    #[test]
    fn calibration_magnitudes() {
        // A 16x16 multiplier in 7nm is a few hundred µm² and sub-ns.
        let m = multiplier(16, 16);
        assert!(m.area_um2() > 50.0 && m.area_um2() < 500.0, "{}", m.area_um2());
        assert!(m.delay_ns() > 0.05 && m.delay_ns() < 0.4, "{}", m.delay_ns());
    }
}
