//! Synthesis cost model — the Design Compiler / TSMC 7 nm substitute
//! (DESIGN.md §3). Component models in [`components`], whole-datapath
//! costing and delay-target sweeps in [`model`] — now parameterized over
//! any technology's [`crate::tech::CostModel`] (the `*_with` variants);
//! the plain functions remain the bit-identical ASIC shorthands.

pub mod components;
pub mod model;

pub use model::{
    breakdown, breakdown_with, sweep, sweep_with, synth_at, synth_at_with, synth_min_delay,
    synth_min_delay_with, Breakdown, SynthPoint,
};
