//! Synthesis cost model — the Design Compiler / TSMC 7 nm substitute
//! (DESIGN.md §3). Component models in [`components`], whole-datapath
//! costing and delay-target sweeps in [`model`].

pub mod components;
pub mod model;

pub use model::{breakdown, sweep, synth_at, synth_min_delay, Breakdown, SynthPoint};
