//! Whole-datapath cost model for a generated interpolator (paper Fig. 1),
//! and the area(delay-target) sweep behind Table I and Figs 2–3.
//!
//! The two parallel paths of the architecture:
//!
//! ```text
//!   path A:  x -> truncate -> square ----\
//!   path B:  r -> LUT (a,b,c) ------------+-> a*sq, b*xl -> 3:2 + CPA -> >>k
//! ```
//!
//! The multiplies start when *both* their operands are ready, so the
//! pre-multiply delay is `max(T_square, T_lut)` — the paper's observation
//! that the square path is usually critical drives its decision procedure
//! (§III), and this model reproduces that: for quadratic designs at the
//! paper's sizes `T_square > T_lut` until `R` grows large.

use super::components::{
    lut, multi_operand_add, multiplier, sizing_multiplier, squarer, Cost,
};
use crate::dse::{Degree, Implementation};
use crate::rtl::encode::field_widths;

/// Per-component cost breakdown of one implementation at max drive.
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub lut: Cost,
    pub squarer: Cost,
    pub mult_a: Cost,
    pub mult_b: Cost,
    pub accumulate: Cost,
    /// Minimum achievable delay, ns.
    pub d_min_ns: f64,
    /// Area at minimum delay... no: area at *relaxed* target, GE.
    pub area_min_ge: f64,
}

/// Structural cost of the implementation (drive-independent).
pub fn breakdown(im: &Implementation) -> Breakdown {
    let (wa, wb, wc) = field_widths(im);
    let xbits = im.x_bits();
    let xs_bits = xbits - im.sq_trunc;
    let xl_bits = xbits - im.lin_trunc;

    let lut_c = lut(im.lookup_bits, wa + wb + wc);
    let (sq_c, ma_c) = if im.degree == Degree::Quadratic {
        (squarer(xs_bits), multiplier(wa + 1, 2 * xs_bits))
    } else {
        (Cost::zero(), Cost::zero())
    };
    let mb_c = multiplier(wb + 1, xl_bits);
    // Accumulator: three operands at the accumulator width.
    let acc_w = (2 * xs_bits + wa).max(wb + xl_bits).max(wc) + 2 + im.k;
    let n_ops = if im.degree == Degree::Quadratic { 3 } else { 2 };
    let add_c = multi_operand_add(n_ops, acc_w);

    let pre_mult = sq_c.delay_fo4.max(lut_c.delay_fo4);
    let mult_path = ma_c.delay_fo4.max(mb_c.delay_fo4 + (lut_c.delay_fo4 - pre_mult).max(0.0));
    let d_min_fo4 = pre_mult + mult_path + add_c.delay_fo4;
    let area_ge =
        lut_c.area_ge + sq_c.area_ge + ma_c.area_ge + mb_c.area_ge + add_c.area_ge;

    Breakdown {
        lut: lut_c,
        squarer: sq_c,
        mult_a: ma_c,
        mult_b: mb_c,
        accumulate: add_c,
        d_min_ns: d_min_fo4 * super::components::FO4_NS,
        area_min_ge: area_ge * 1.10, // 10% wiring/misc overhead
    }
}

/// One synthesis result: the model's analogue of a DC run at a delay
/// target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SynthPoint {
    pub delay_ns: f64,
    pub area_um2: f64,
}

impl SynthPoint {
    pub fn area_delay(&self) -> f64 {
        self.delay_ns * self.area_um2
    }
}

/// "Synthesize" at a delay target: returns the achieved delay (the target,
/// when achievable) and the sized area. Targets below `d_min` are clamped
/// to `d_min` (DC reports a violated path; we report the floor).
pub fn synth_at(im: &Implementation, target_ns: f64) -> SynthPoint {
    let b = breakdown(im);
    let d = target_ns.max(b.d_min_ns);
    let mult = sizing_multiplier(b.d_min_ns, d);
    SynthPoint {
        delay_ns: d,
        area_um2: b.area_min_ge * mult * super::components::GE_UM2,
    }
}

/// The minimum-obtainable-delay point (Table I's operating point).
pub fn synth_min_delay(im: &Implementation) -> SynthPoint {
    let b = breakdown(im);
    synth_at(im, b.d_min_ns)
}

/// Full area-delay profile (Fig. 2 / Fig. 3): `n` targets from `d_min` to
/// `relax * d_min`, geometrically spaced.
pub fn sweep(im: &Implementation, n: usize, relax: f64) -> Vec<SynthPoint> {
    let b = breakdown(im);
    (0..n)
        .map(|i| {
            let f = (relax.ln() * i as f64 / (n - 1).max(1) as f64).exp();
            synth_at(im, b.d_min_ns * f)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};

    fn demo(name: &str, bits: u32, r: u32) -> Implementation {
        let f = builtin(name, bits).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
        explore(&bt, &ds, &DseOptions::default()).unwrap()
    }

    #[test]
    fn paper_magnitudes_10bit() {
        // Paper Table I: 10-bit recip, 6 lookup bits (linear): 43 µm² at
        // 0.125 ns. The model should land within ~2-3x of both.
        let im = demo("recip", 10, 6);
        let p = synth_min_delay(&im);
        assert!(p.delay_ns > 0.04 && p.delay_ns < 0.4, "delay {}", p.delay_ns);
        assert!(p.area_um2 > 10.0 && p.area_um2 < 250.0, "area {}", p.area_um2);
    }

    #[test]
    fn sweep_is_monotone_banana() {
        let im = demo("log2", 10, 5);
        let pts = sweep(&im, 12, 2.5);
        for w in pts.windows(2) {
            assert!(w[1].delay_ns > w[0].delay_ns);
            assert!(w[1].area_um2 <= w[0].area_um2 + 1e-9, "area must relax with delay");
        }
        // Meaningful dynamic range.
        assert!(pts[0].area_um2 > 1.5 * pts.last().unwrap().area_um2);
    }

    #[test]
    fn linear_cheaper_than_quadratic_same_function() {
        // Same function/precision: a linear design (higher R) at min delay
        // should be faster than the quadratic (it drops squarer+mult).
        let quad = demo("recip", 10, 4);
        let lin = demo("recip", 10, 7);
        if quad.degree == Degree::Quadratic && lin.degree == Degree::Linear {
            let pq = synth_min_delay(&quad);
            let pl = synth_min_delay(&lin);
            assert!(pl.delay_ns < pq.delay_ns, "linear should be faster");
        }
    }

    #[test]
    fn truncation_reduces_cost() {
        // Force zero truncation and compare: the DSE's truncations must pay.
        let im = demo("recip", 10, 4);
        if im.degree != Degree::Quadratic || im.sq_trunc == 0 {
            return;
        }
        let mut untrunc = im.clone();
        untrunc.sq_trunc = 0;
        untrunc.lin_trunc = 0;
        let a = synth_min_delay(&im);
        let b = synth_min_delay(&untrunc);
        assert!(
            a.area_um2 < b.area_um2,
            "truncated {} >= untruncated {}",
            a.area_um2,
            b.area_um2
        );
    }

    #[test]
    fn breakdown_components_positive_for_quadratic() {
        let im = demo("recip", 10, 4);
        if im.degree != Degree::Quadratic {
            return;
        }
        let b = breakdown(&im);
        assert!(b.lut.area_ge > 0.0);
        assert!(b.squarer.area_ge > 0.0);
        assert!(b.mult_a.area_ge > 0.0);
        assert!(b.mult_b.area_ge > 0.0);
        assert!(b.accumulate.area_ge > 0.0);
        assert!(b.d_min_ns > 0.0);
    }
}
