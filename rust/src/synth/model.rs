//! Whole-datapath cost model for a generated interpolator (paper Fig. 1),
//! and the area(delay-target) sweep behind Table I and Figs 2–3.
//!
//! The two parallel paths of the architecture:
//!
//! ```text
//!   path A:  x -> truncate -> square ----\
//!   path B:  r -> LUT (a,b,c) ------------+-> a*sq, b*xl -> 3:2 + CPA -> >>k
//! ```
//!
//! The multiplies start when *both* their operands are ready, so the
//! pre-multiply delay is `max(T_square, T_lut)` — the paper's observation
//! that the square path is usually critical drives its decision procedure
//! (§III), and this model reproduces that: for quadratic designs at the
//! paper's sizes `T_square > T_lut` until `R` grows large.
//!
//! The datapath *composition* is technology-independent; the component
//! primitives come from a [`CostModel`]. The `*_with` functions take any
//! cost model; the plain functions are the [`AsicGe`] shorthands and
//! reproduce the pre-trait numbers bit-for-bit.

use crate::dse::{Degree, Implementation};
use crate::rtl::encode::field_widths;
use crate::tech::{AsicGe, CostModel};

/// Per-component cost breakdown of one implementation at max drive.
/// Areas/delays are in the cost model's technology units (gate
/// equivalents / FO4 for [`AsicGe`]).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub lut: super::components::Cost,
    pub squarer: super::components::Cost,
    pub mult_a: super::components::Cost,
    pub mult_b: super::components::Cost,
    pub accumulate: super::components::Cost,
    /// Minimum achievable delay, ns.
    pub d_min_ns: f64,
    /// Base area in technology units (GE for [`AsicGe`]): the area at a
    /// fully *relaxed* delay target, before the delay-target sizing of
    /// [`CostModel::sizing_multiplier`] scales it up. Includes the
    /// technology's wiring/misc overhead. (Despite the historical field
    /// name, this is the *minimum area*, not the area at minimum delay.)
    pub area_min_ge: f64,
}

/// Structural cost of the implementation under the ASIC gate model
/// (drive-independent). Shorthand for [`breakdown_with`] with [`AsicGe`].
pub fn breakdown(im: &Implementation) -> Breakdown {
    breakdown_with(&AsicGe, im)
}

/// Structural cost of the implementation under any technology's
/// [`CostModel`].
pub fn breakdown_with(cm: &dyn CostModel, im: &Implementation) -> Breakdown {
    let (wa, wb, wc) = field_widths(im);
    let xbits = im.x_bits();
    let xs_bits = xbits - im.sq_trunc;
    let xl_bits = xbits - im.lin_trunc;

    let lut_c = cm.lut(im.lookup_bits, wa + wb + wc);
    let (sq_c, ma_c) = if im.degree == Degree::Quadratic {
        (cm.squarer(xs_bits), cm.multiplier(wa + 1, 2 * xs_bits))
    } else {
        (super::components::Cost::zero(), super::components::Cost::zero())
    };
    let mb_c = cm.multiplier(wb + 1, xl_bits);
    // Accumulator: three operands at the accumulator width.
    let acc_w = (2 * xs_bits + wa).max(wb + xl_bits).max(wc) + 2 + im.k;
    let n_ops = if im.degree == Degree::Quadratic { 3 } else { 2 };
    let add_c = cm.multi_operand_add(n_ops, acc_w);

    let pre_mult = sq_c.delay_fo4.max(lut_c.delay_fo4);
    let mult_path = ma_c.delay_fo4.max(mb_c.delay_fo4 + (lut_c.delay_fo4 - pre_mult).max(0.0));
    let d_min_units = pre_mult + mult_path + add_c.delay_fo4;
    let area =
        lut_c.area_ge + sq_c.area_ge + ma_c.area_ge + mb_c.area_ge + add_c.area_ge;

    Breakdown {
        lut: lut_c,
        squarer: sq_c,
        mult_a: ma_c,
        mult_b: mb_c,
        accumulate: add_c,
        d_min_ns: d_min_units * cm.delay_unit_ns(),
        area_min_ge: area * cm.wiring_overhead(),
    }
}

/// One synthesis result: the model's analogue of a DC run at a delay
/// target. `area_um2` is in the cost model's report units (µm² for
/// [`AsicGe`], native LUT6s for the FPGA model).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SynthPoint {
    pub delay_ns: f64,
    pub area_um2: f64,
}

impl SynthPoint {
    pub fn area_delay(&self) -> f64 {
        self.delay_ns * self.area_um2
    }
}

/// "Synthesize" at a delay target: returns the achieved delay (the target,
/// when achievable) and the sized area. Targets below `d_min` are clamped
/// to `d_min` (DC reports a violated path; we report the floor).
pub fn synth_at(im: &Implementation, target_ns: f64) -> SynthPoint {
    synth_at_with(&AsicGe, im, target_ns)
}

/// [`synth_at`] under any technology's cost model.
pub fn synth_at_with(cm: &dyn CostModel, im: &Implementation, target_ns: f64) -> SynthPoint {
    let b = breakdown_with(cm, im);
    let d = target_ns.max(b.d_min_ns);
    let mult = cm.sizing_multiplier(b.d_min_ns, d);
    SynthPoint {
        delay_ns: d,
        area_um2: b.area_min_ge * mult * cm.area_unit_um2(),
    }
}

/// The minimum-obtainable-delay point (Table I's operating point).
pub fn synth_min_delay(im: &Implementation) -> SynthPoint {
    synth_min_delay_with(&AsicGe, im)
}

/// [`synth_min_delay`] under any technology's cost model.
pub fn synth_min_delay_with(cm: &dyn CostModel, im: &Implementation) -> SynthPoint {
    let b = breakdown_with(cm, im);
    synth_at_with(cm, im, b.d_min_ns)
}

/// Full area-delay profile (Fig. 2 / Fig. 3): `n` targets from `d_min` to
/// `relax * d_min`, geometrically spaced.
pub fn sweep(im: &Implementation, n: usize, relax: f64) -> Vec<SynthPoint> {
    sweep_with(&AsicGe, im, n, relax)
}

/// [`sweep`] under any technology's cost model.
pub fn sweep_with(
    cm: &dyn CostModel,
    im: &Implementation,
    n: usize,
    relax: f64,
) -> Vec<SynthPoint> {
    let b = breakdown_with(cm, im);
    (0..n)
        .map(|i| {
            let f = (relax.ln() * i as f64 / (n - 1).max(1) as f64).exp();
            synth_at_with(cm, im, b.d_min_ns * f)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{builtin, AccuracySpec, BoundTable};
    use crate::designspace::{generate, GenOptions};
    use crate::dse::{explore, DseOptions};
    use crate::tech::TechKind;

    fn demo(name: &str, bits: u32, r: u32) -> Implementation {
        let f = builtin(name, bits).unwrap();
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let ds = generate(&bt, &GenOptions { lookup_bits: r, ..Default::default() }).unwrap();
        explore(&bt, &ds, &DseOptions::default()).unwrap()
    }

    #[test]
    fn paper_magnitudes_10bit() {
        // Paper Table I: 10-bit recip, 6 lookup bits (linear): 43 µm² at
        // 0.125 ns. The model should land within ~2-3x of both.
        let im = demo("recip", 10, 6);
        let p = synth_min_delay(&im);
        assert!(p.delay_ns > 0.04 && p.delay_ns < 0.4, "delay {}", p.delay_ns);
        assert!(p.area_um2 > 10.0 && p.area_um2 < 250.0, "area {}", p.area_um2);
    }

    #[test]
    fn sweep_is_monotone_banana() {
        let im = demo("log2", 10, 5);
        let pts = sweep(&im, 12, 2.5);
        for w in pts.windows(2) {
            assert!(w[1].delay_ns > w[0].delay_ns);
            assert!(w[1].area_um2 <= w[0].area_um2 + 1e-9, "area must relax with delay");
        }
        // Meaningful dynamic range.
        assert!(pts[0].area_um2 > 1.5 * pts.last().unwrap().area_um2);
    }

    #[test]
    fn linear_cheaper_than_quadratic_same_function() {
        // Same function/precision: a linear design (higher R) at min delay
        // should be faster than the quadratic (it drops squarer+mult).
        let quad = demo("recip", 10, 4);
        let lin = demo("recip", 10, 7);
        if quad.degree == Degree::Quadratic && lin.degree == Degree::Linear {
            let pq = synth_min_delay(&quad);
            let pl = synth_min_delay(&lin);
            assert!(pl.delay_ns < pq.delay_ns, "linear should be faster");
        }
    }

    #[test]
    fn truncation_reduces_cost() {
        // Force zero truncation and compare: the DSE's truncations must pay.
        let im = demo("recip", 10, 4);
        if im.degree != Degree::Quadratic || im.sq_trunc == 0 {
            return;
        }
        let mut untrunc = im.clone();
        untrunc.sq_trunc = 0;
        untrunc.lin_trunc = 0;
        let a = synth_min_delay(&im);
        let b = synth_min_delay(&untrunc);
        assert!(
            a.area_um2 < b.area_um2,
            "truncated {} >= untruncated {}",
            a.area_um2,
            b.area_um2
        );
    }

    #[test]
    fn breakdown_components_positive_for_quadratic() {
        let im = demo("recip", 10, 4);
        if im.degree != Degree::Quadratic {
            return;
        }
        let b = breakdown(&im);
        assert!(b.lut.area_ge > 0.0);
        assert!(b.squarer.area_ge > 0.0);
        assert!(b.mult_a.area_ge > 0.0);
        assert!(b.mult_b.area_ge > 0.0);
        assert!(b.accumulate.area_ge > 0.0);
        assert!(b.d_min_ns > 0.0);
    }

    #[test]
    fn asic_shorthand_is_bit_identical_to_trait_path() {
        // The free functions are AsicGe delegations: costing through the
        // trait layer must not perturb a single bit of Table I.
        let im = demo("recip", 10, 4);
        let cm = TechKind::AsicGe.technology().cost_model();
        let a = breakdown(&im);
        let b = breakdown_with(cm, &im);
        assert_eq!(a.d_min_ns.to_bits(), b.d_min_ns.to_bits());
        assert_eq!(a.area_min_ge.to_bits(), b.area_min_ge.to_bits());
        let pa = synth_at(&im, 0.3);
        let pb = synth_at_with(cm, &im, 0.3);
        assert_eq!(pa.delay_ns.to_bits(), pb.delay_ns.to_bits());
        assert_eq!(pa.area_um2.to_bits(), pb.area_um2.to_bits());
    }

    #[test]
    fn technologies_cost_the_same_design_differently() {
        let im = demo("recip", 10, 4);
        let asic = synth_min_delay_with(TechKind::AsicGe.technology().cost_model(), &im);
        let fpga = synth_min_delay_with(TechKind::FpgaLut6.technology().cost_model(), &im);
        let low = synth_min_delay_with(TechKind::LowPower.technology().cost_model(), &im);
        // FPGA logic levels are far slower than 7nm FO4s.
        assert!(fpga.delay_ns > 3.0 * asic.delay_ns, "{} vs {}", fpga.delay_ns, asic.delay_ns);
        // Activity weighting strictly discounts the energy proxy.
        assert!(low.area_um2 < asic.area_um2);
        // Same timing model for low-power.
        assert_eq!(low.delay_ns.to_bits(), asic.delay_ns.to_bits());
    }
}
