//! E2 — regenerate paper Table II (LUT widths vs FloPoCo-like at equal
//! height, quadratic). `cargo bench --bench table2 [-- --deep]`.
fn main() {
    let deep = std::env::args().any(|a| a == "--deep");
    let mut cases = vec![("recip", 16u32, 6u32), ("log2", 16, 6), ("exp2", 10, 4)];
    if deep {
        cases.push(("recip", 20, 9));
        cases.push(("log2", 20, 9));
    }
    let text = polygen::report::table2(&cases);
    println!("{text}");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table2.txt", &text).ok();
}
