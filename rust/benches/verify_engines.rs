//! E7/perf — verification engine throughput: scalar Rust vs the AOT XLA
//! graph (jnp flavor) vs the interpret-mode Pallas flavor, exhaustive over
//! a 16-bit design. Skips engines whose artifacts are missing.
//!
//! The design under test comes from one pipeline run; the timed loops
//! call the engine-parameterized verifier directly (the pipeline's
//! one-shot `verify()` stage is the wrong shape for a 5-rep median).
use std::time::Instant;

use polygen::pipeline::{verify_implementation, Engine, Flavor, Pipeline, XlaRuntime};

fn main() {
    let explored = Pipeline::function("recip")
        .bits(16)
        .lub(8)
        .threads(8)
        .prepare()
        .unwrap()
        .generate()
        .unwrap()
        .explore()
        .unwrap();
    let bt = &explored.workload.bt;
    let im = &explored.implementation;
    let total = 1u64 << 16;
    let mut out = String::from("verify engine throughput (recip 16-bit, 65536 inputs)\n");

    let mut bench = |label: &str, engine: &Engine<'_>| {
        // Warm once, then median of 5.
        let _ = verify_implementation(bt, im, engine).unwrap();
        let mut ts: Vec<f64> = (0..5)
            .map(|_| {
                let t0 = Instant::now();
                let rep = verify_implementation(bt, im, engine).unwrap();
                assert!(rep.ok());
                t0.elapsed().as_secs_f64()
            })
            .collect();
        ts.sort_by(f64::total_cmp);
        let med = ts[2];
        let line = format!(
            "  {label:<12} {:>10.3} ms   {:>8.1} Minputs/s\n",
            med * 1e3,
            total as f64 / med / 1e6
        );
        print!("{line}");
        out.push_str(&line);
    };

    bench("scalar", &Engine::Scalar);
    match XlaRuntime::load("artifacts") {
        Ok(rt) => {
            bench("xla-jnp", &Engine::Xla { rt: &rt, flavor: Flavor::Jnp });
            if rt.has_flavor(Flavor::Pallas) {
                bench("xla-pallas", &Engine::Xla { rt: &rt, flavor: Flavor::Pallas });
            }
        }
        Err(e) => println!("  (xla engines skipped: {e})"),
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/verify_engines.txt", out).ok();
}
