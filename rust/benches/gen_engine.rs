//! Reproducible perf harness for the generation engine (§Perf: envelope
//! enumeration; §Scaling: lazy regions). Times complete-space generation
//! for recip/log2/exp2 at 12/14/16 bits over several `R` (gated), plus
//! the activation workloads as a non-gating `activations` section:
//!
//! - `lazy` — [`generate`]: analysis phases + common `k` only (what the
//!   pipeline runs; entries sweep on demand),
//! - `env`  — [`generate_eager`]: the eager envelope engine, single- and
//!   multi-threaded (the apples-to-apples successor of the pre-lazy
//!   `generate`, so the `envelope_*` metrics stay comparable across the
//!   committed baselines),
//! - `naive` — the retained pre-envelope oracle on flagged workloads.
//!
//! All engines are measured in the same run with their spaces checked
//! identical. Writes machine-readable `BENCH_gen.json` at the repository
//! root so the perf trajectory is tracked across PRs — CI regenerates it
//! natively in the smoke profile and gates on regressions against the
//! committed baseline (`python/bench_gate.py`).
//!
//! ```text
//! cargo bench --bench gen_engine             # full run
//! cargo bench --bench gen_engine -- --smoke  # CI smoke profile
//! ```

use std::fmt::Write as _;
use std::time::Instant;

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, generate_eager, generate_naive, DesignSpace, GenOptions};

struct Case {
    func: &'static str,
    bits: u32,
    r: u32,
    /// Also time the pre-envelope oracle (slow at 16 bits — flagged).
    with_naive: bool,
}

const fn case(func: &'static str, bits: u32, r: u32, with_naive: bool) -> Case {
    Case { func, bits, r, with_naive }
}

const FULL: &[Case] = &[
    case("recip", 12, 5, true),
    case("recip", 14, 6, true),
    case("recip", 16, 6, true),
    case("log2", 12, 5, false),
    case("log2", 14, 6, false),
    case("log2", 16, 7, true),
    case("exp2", 12, 5, false),
    case("exp2", 14, 6, false),
    case("exp2", 16, 6, false),
];

const SMOKE: &[Case] = &[case("recip", 12, 5, true), case("log2", 12, 5, false)];

/// Activation workloads (PR 9) — tracked but NON-GATING: their rows land
/// in a separate `activations` JSON array that `python/bench_gate.py`
/// never reads, so their trajectory is recorded without arming a gate
/// while the case set is still settling.
const ACTIVATIONS: &[Case] = &[
    case("tanh", 12, 6, false),
    case("sigmoid", 12, 6, false),
    case("gelu", 12, 6, false),
    case("softplus", 12, 6, false),
    case("tanh", 16, 9, false),
];

const ACTIVATIONS_SMOKE: &[Case] = &[case("tanh", 12, 6, false)];

fn time_median<R>(reps: usize, mut f: impl FnMut() -> R) -> (f64, R) {
    let mut times = Vec::with_capacity(reps);
    let t0 = Instant::now();
    let mut out = f();
    times.push(t0.elapsed().as_secs_f64());
    for _ in 1..reps {
        let t0 = Instant::now();
        out = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    (times[times.len() / 2], out)
}

fn assert_identical(a: &DesignSpace, b: &DesignSpace) {
    assert_eq!(a.k, b.k, "engines disagree on k");
    assert_eq!(a.num_regions(), b.num_regions());
    for (ra, rb) in a.region_views().zip(b.region_views()) {
        assert_eq!(ra.entries(), rb.entries(), "engines disagree in region {}", ra.r());
        assert_eq!(
            ra.space().linear_ok,
            rb.space().linear_ok,
            "engines disagree in region {}",
            ra.r()
        );
    }
}

struct Row {
    func: &'static str,
    bits: u32,
    r: u32,
    k: u32,
    ab_pairs: u64,
    lazy_1t: f64,
    env_1t: f64,
    env_mt: f64,
    naive_1t: Option<f64>,
}

fn run_cases(cases: &[Case], threads: usize, smoke: bool) -> Vec<Row> {
    let mut rows: Vec<Row> = Vec::new();

    for c in cases {
        let f = builtin(c.func, c.bits).expect("builtin function");
        let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
        let o1 = GenOptions { lookup_bits: c.r, threads: 1, ..Default::default() };
        let omt = GenOptions { lookup_bits: c.r, threads, ..Default::default() };
        let reps = if smoke || c.bits >= 16 { 1 } else { 3 };

        // Lazy: what `generate` now costs (no entry sweep).
        let (lazy_1t, lazy_ds) = time_median(reps, || generate(&bt, &o1));
        let lazy_ds = match lazy_ds {
            Ok(ds) => ds,
            Err(e) => {
                println!("{:>5} {:>2}b R={}  SKIPPED: {e}", c.func, c.bits, c.r);
                continue;
            }
        };
        // Eager: the full materialization the pre-lazy engine always paid
        // (metric name `envelope_*` kept for baseline comparability).
        let (env_1t, ds) = time_median(reps, || generate_eager(&bt, &o1).expect("eager"));
        let (env_mt, ds_mt) =
            time_median(reps, || generate_eager(&bt, &omt).expect("mt generation"));
        assert_identical(&ds, &ds_mt);
        assert_identical(&ds, &lazy_ds); // materializes the lazy space's views

        let naive_1t = if c.with_naive {
            let (t, nds) =
                time_median(1, || generate_naive(&bt, &o1).expect("oracle generation"));
            assert_identical(&ds, &nds);
            Some(t)
        } else {
            None
        };

        let speedup = naive_1t.map(|t| t / env_1t.max(1e-12));
        println!(
            "{:>5} {:>2}b R={}  k={:<2} pairs={:<9} lazy_1t={:>8.2} ms  env_1t={:>8.2} ms  \
             env_{}t={:>8.2} ms{}",
            c.func,
            c.bits,
            c.r,
            ds.k,
            ds.num_ab_pairs(),
            lazy_1t * 1e3,
            env_1t * 1e3,
            threads,
            env_mt * 1e3,
            match (naive_1t, speedup) {
                (Some(t), Some(s)) => format!("  naive_1t={:>9.2} ms  speedup={s:.2}x", t * 1e3),
                _ => String::new(),
            }
        );
        rows.push(Row {
            func: c.func,
            bits: c.bits,
            r: c.r,
            k: ds.k,
            ab_pairs: ds.num_ab_pairs(),
            lazy_1t,
            env_1t,
            env_mt,
            naive_1t,
        });
    }
    rows
}

fn json_row(r: &Row) -> String {
    format!(
        "{{\"func\": \"{}\", \"bits\": {}, \"lookup_bits\": {}, \"k\": {}, \
         \"ab_pairs\": {}, \"lazy_1t_s\": {:.6}, \"envelope_1t_s\": {:.6}, \
         \"envelope_mt_s\": {:.6}, \"naive_1t_s\": {}, \"speedup_vs_naive\": {}}}",
        r.func,
        r.bits,
        r.r,
        r.k,
        r.ab_pairs,
        r.lazy_1t,
        r.env_1t,
        r.env_mt,
        r.naive_1t.map_or("null".to_string(), |t| format!("{t:.6}")),
        r.naive_1t.map_or("null".to_string(), |t| format!("{:.3}", t / r.env_1t.max(1e-12))),
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let rows = run_cases(if smoke { SMOKE } else { FULL }, threads, smoke);
    let act_rows =
        run_cases(if smoke { ACTIVATIONS_SMOKE } else { ACTIVATIONS }, threads, smoke);

    // Machine-readable trajectory record at the repository root.
    let headline = rows
        .iter()
        .find(|r| r.func == "recip" && r.bits == 16 && r.r == 6)
        .and_then(|r| r.naive_1t.map(|t| t / r.env_1t.max(1e-12)));
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"gen_engine\",");
    let _ = writeln!(json, "  \"harness\": \"cargo-bench\",");
    let _ = writeln!(json, "  \"mode\": \"{}\",", if smoke { "smoke" } else { "full" });
    let _ = writeln!(json, "  \"threads_multi\": {threads},");
    let _ = writeln!(
        json,
        "  \"headline_speedup_recip16_r6\": {},",
        headline.map_or("null".to_string(), |s| format!("{s:.3}"))
    );
    let _ = writeln!(json, "  \"results\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        let _ = writeln!(json, "    {}{}", json_row(r), comma);
    }
    let _ = writeln!(json, "  ],");
    // Non-gating section: same schema, ignored by python/bench_gate.py
    // (which only reads "results").
    let _ = writeln!(json, "  \"activations\": [");
    for (i, r) in act_rows.iter().enumerate() {
        let comma = if i + 1 == act_rows.len() { "" } else { "," };
        let _ = writeln!(json, "    {}{}", json_row(r), comma);
    }
    let _ = writeln!(json, "  ]");
    json.push_str("}\n");

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gen.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
