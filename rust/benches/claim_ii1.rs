//! E5 — Claim II.1: naive vs pruned divided-difference search, measured on
//! the paper's 16-bit reciprocal generation workload (paper: ~5x).
fn main() {
    let mut out = String::new();
    for (bits, lub, reps) in [(12u32, 5u32, 3usize), (16, 8, 3), (16, 7, 1)] {
        let s = polygen::report::claim_ii1("recip", bits, lub, reps);
        println!("{s}");
        out.push_str(&s);
        out.push('\n');
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/claim_ii1.txt", out).ok();
}
