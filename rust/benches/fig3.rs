//! E4 — regenerate paper Fig. 3 (area-delay per LUT height, log2 10- and
//! 16-bit, all feasible heights, labels = lookup bits).
fn main() {
    std::fs::create_dir_all("results").ok();
    for bits in [10u32, 16] {
        let (text, csv) = polygen::report::fig3("log2", bits, 8);
        println!("{text}");
        std::fs::write(format!("results/fig3_log2_{bits}.csv"), csv).ok();
        std::fs::write(format!("results/fig3_log2_{bits}.txt"), &text).ok();
    }
    // E8 companion: where does linear become feasible?
    for f in ["recip", "log2", "exp2"] {
        print!("{}", polygen::report::linear_threshold(f, 16));
    }
}
