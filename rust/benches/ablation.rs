//! Ablation (paper §III): the paper reports that alternative decision
//! procedures "such as prioritizing LUT optimization ... yielded inferior
//! area-delay profiles". Compare SquareFirst (the paper's) vs LutFirst on
//! the Table I workloads, plus forced-degree ablations.
use polygen::bounds::AccuracySpec;
use polygen::coordinator::Workload;
use polygen::designspace::{generate, GenOptions};
use polygen::dse::{explore, Degree, DseOptions, Procedure};
use polygen::synth::synth_min_delay;

fn main() {
    let mut out = String::from(
        "ABLATION - decision procedure variants (min-delay ADP, lower is better)\n",
    );
    out.push_str(&format!(
        "{:<8} {:>4} {:>4} | {:>12} {:>12} | {:>12}\n",
        "func", "bits", "LUB", "square-first", "lut-first", "forced-quad"
    ));
    for (name, bits, lub) in
        [("recip", 10u32, 5u32), ("recip", 16, 8), ("log2", 16, 8), ("exp2", 10, 5)]
    {
        let w = Workload::prepare(name, bits, AccuracySpec::Ulp(1)).unwrap();
        let ds = generate(
            &w.bt,
            &GenOptions { lookup_bits: lub, threads: 8, ..Default::default() },
        )
        .unwrap();
        let adp = |proc_: Procedure, deg: Option<Degree>| -> String {
            explore(&w.bt, &ds, &DseOptions { procedure: proc_, degree: deg, ..Default::default() })
                .map(|im| format!("{:.1}", synth_min_delay(&im).area_delay()))
                .unwrap_or_else(|| "-".into())
        };
        let line = format!(
            "{:<8} {:>4} {:>4} | {:>12} {:>12} | {:>12}\n",
            name,
            bits,
            lub,
            adp(Procedure::SquareFirst, None),
            adp(Procedure::LutFirst, None),
            adp(Procedure::SquareFirst, Some(Degree::Quadratic)),
        );
        print!("{line}");
        out.push_str(&line);
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation.txt", out).ok();
}
