//! Ablation (paper §III): the paper reports that alternative decision
//! procedures "such as prioritizing LUT optimization ... yielded inferior
//! area-delay profiles". Compare SquareFirst (the paper's) vs LutFirst
//! vs the cost-guided Pareto procedure on the Table I workloads, plus
//! forced-degree ablations — all costed under the ASIC model, so the
//! columns are directly comparable.
//!
//! Each variant is a pipeline run; a shared disk cache means the complete
//! space is generated once per workload and re-read for the other
//! variants.
use polygen::pipeline::{Degree, Pipeline, Procedure};

fn main() {
    let cache = std::env::temp_dir().join("polygen_ablation_cache");
    let mut out = String::from(
        "ABLATION - decision procedure variants (min-delay ADP, lower is better)\n",
    );
    out.push_str(&format!(
        "{:<8} {:>4} {:>4} | {:>12} {:>12} {:>12} | {:>12}\n",
        "func", "bits", "LUB", "square-first", "lut-first", "pareto", "forced-quad"
    ));
    for (name, bits, lub) in
        [("recip", 10u32, 5u32), ("recip", 16, 8), ("log2", 16, 8), ("exp2", 10, 5)]
    {
        let adp = |procedure: Procedure, degree: Option<Degree>| -> String {
            let mut p = Pipeline::function(name)
                .bits(bits)
                .lub(lub)
                .threads(8)
                .procedure(procedure)
                .cache_dir(&cache);
            if let Some(d) = degree {
                p = p.degree(d);
            }
            p.prepare()
                .and_then(|prepared| prepared.generate())
                .and_then(|spaced| spaced.explore())
                .map(|explored| format!("{:.1}", explored.synthesize().synth.area_delay()))
                .unwrap_or_else(|_| "-".into())
        };
        let line = format!(
            "{:<8} {:>4} {:>4} | {:>12} {:>12} {:>12} | {:>12}\n",
            name,
            bits,
            lub,
            adp(Procedure::SquareFirst, None),
            adp(Procedure::LutFirst, None),
            adp(Procedure::Pareto, None),
            adp(Procedure::SquareFirst, Some(Degree::Quadratic)),
        );
        print!("{line}");
        out.push_str(&line);
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation.txt", out).ok();
    std::fs::remove_dir_all(&cache).ok();
}
