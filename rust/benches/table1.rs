//! E1 — regenerate paper Table I (min-delay synthesis vs DesignWare-like).
//! `cargo bench --bench table1 [-- --deep]` ; output also lands in
//! results/table1.txt.
fn main() {
    let deep = std::env::args().any(|a| a == "--deep");
    let mut sizes: Vec<(&str, u32)> = vec![
        ("recip", 10),
        ("recip", 16),
        ("log2", 10),
        ("log2", 16),
        ("exp2", 10),
        ("exp2", 16),
    ];
    if deep {
        // The paper's 23-bit rows took 39-78 h on its setup; 20-bit is the
        // practical deep setting here (same code path, exponential wall).
        sizes.push(("recip", 20));
        sizes.push(("log2", 20));
    }
    let text = polygen::report::table1(&sizes, 8);
    println!("{text}");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table1.txt", &text).ok();
}
