//! Perf/extension — thread scaling of design-space generation (the
//! paper's "parallelism" future-work item): per-region analysis across
//! worker threads on a 16-bit reciprocal with large regions.
use std::time::Instant;

use polygen::bounds::{builtin, AccuracySpec, BoundTable};
use polygen::designspace::{generate, GenOptions};

fn main() {
    let f = builtin("recip", 16).unwrap();
    let bt = BoundTable::build(f.as_ref(), AccuracySpec::Ulp(1));
    let mut out = String::from("generation thread scaling (recip 16-bit, R=6)\n");
    let mut t1 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let opts = GenOptions { lookup_bits: 6, threads, ..Default::default() };
        let t0 = Instant::now();
        let ds = generate(&bt, &opts).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        if threads == 1 {
            t1 = dt;
        }
        let line = format!(
            "  threads={threads:<2} {:>8.2} s  speedup {:>4.2}x  (k={})\n",
            dt,
            t1 / dt,
            ds.k
        );
        print!("{line}");
        out.push_str(&line);
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/gen_parallel.txt", out).ok();
}
