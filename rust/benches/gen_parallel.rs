//! Perf/extension — thread scaling of design-space generation (the
//! paper's "parallelism" future-work item): per-region analysis across
//! worker threads on a 16-bit reciprocal with large regions, measured
//! through the pipeline's generation stage.
use polygen::pipeline::Pipeline;

fn main() {
    let mut out = String::from("generation thread scaling (recip 16-bit, R=6)\n");
    let mut t1 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let spaced = Pipeline::function("recip")
            .bits(16)
            .lub(6)
            .threads(threads)
            .prepare()
            .unwrap()
            .generate()
            .unwrap();
        let dt = spaced.gen_time.as_secs_f64();
        if threads == 1 {
            t1 = dt;
        }
        let line = format!(
            "  threads={threads:<2} {:>8.2} s  speedup {:>4.2}x  (k={})\n",
            dt,
            t1 / dt,
            spaced.space.k
        );
        print!("{line}");
        out.push_str(&line);
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/gen_parallel.txt", out).ok();
}
