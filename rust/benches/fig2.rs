//! E3 — regenerate paper Fig. 2 (area-delay profile, reciprocal with 7
//! lookup bits, vs the DW-like family re-selected per delay target).
//! Paper uses 23-bit; default here is 16-bit (same code path), 20-bit
//! under `-- --deep`.
fn main() {
    let deep = std::env::args().any(|a| a == "--deep");
    let bits = if deep { 20 } else { 16 };
    let (text, csv) = polygen::report::fig2("recip", bits, 7, 14);
    println!("{text}");
    std::fs::create_dir_all("results").ok();
    std::fs::write(format!("results/fig2_recip{bits}.csv"), csv).ok();
    std::fs::write(format!("results/fig2_recip{bits}.txt"), &text).ok();
}
