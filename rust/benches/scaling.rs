//! E6 — §II-A empirical runtime scaling of generation vs lookup bits R
//! (paper: ~O(R^-3) on a 16-bit design; exponential in precision).
fn main() {
    let mut out = String::new();
    let s = polygen::report::scaling("recip", 16, &[6, 7, 8, 9, 10, 11]);
    println!("{s}");
    out.push_str(&s);
    // Precision scaling (the exponential wall): same R, growing bits.
    let s2 = polygen::report::scaling("recip", 14, &[6, 7, 8, 9]);
    println!("{s2}");
    out.push_str(&s2);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/scaling.txt", out).ok();
}
