//! polygen-lint: AST lints for invariants clippy cannot express.
//!
//! The repo carries three invariants that are *structural* — they hold
//! across files, not within an expression — plus one local footgun, and
//! all four have already caused (or nearly caused) real bugs:
//!
//! | rule            | invariant |
//! |-----------------|-----------|
//! | `sync-imports`  | no raw `std::sync` primitive outside `src/sync.rs` — a raw `Mutex` in a modeled protocol silently un-checks the loom model |
//! | `fault-taps`    | every outbound-I/O function in the service/cache/runtime boundary files calls `faults::inject`, and every site literal matches `faults::SITES` (both directions) |
//! | `overflow`      | no unchecked `*`/`+`/`<<` in the exact-arithmetic files (`rational.rs`, `wide.rs`, `designspace/{envelope,extrema}.rs`) — the `RawFrac::lt` wrap was a real completeness bug |
//! | `lock-unwrap`   | no `.unwrap()` on lock/wait results in service-facing modules — poison must be recovered (`sync::plock`), not cascaded |
//! | `obs-registry`  | every `obs::metrics::METRICS` entry has a `counter`/`gauge`/`histogram` use site and vice versa (both directions) — a dead metric lies in every scrape, an unregistered name is a compile error the lint catches before rustc |
//!
//! A finding is silenced with a waiver comment carrying a mandatory
//! reason: `// lint: overflow-ok(reason)` (`sync-ok`, `fault-ok`,
//! `lock-ok` likewise). A waiver covers its own line and the next three
//! lines, so it can sit trailing, directly above the flagged line, or
//! directly above an `fn` signature — the fn-signature form waives the
//! whole body (the waiver kinds are checked per finding, so an
//! `overflow-ok` never silences a sync finding).
//!
//! `#[cfg(test)]` modules and `#[test]` functions are skipped: tests may
//! use raw primitives and wrapping arithmetic freely — they are never
//! loom-modeled and never on the proof path.

use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Which rules run on a file.
#[derive(Debug, Clone, Copy, Default)]
pub struct RuleSet {
    pub sync: bool,
    pub taps: bool,
    pub overflow: bool,
    pub lock_unwrap: bool,
}

impl RuleSet {
    pub fn all() -> RuleSet {
        RuleSet { sync: true, taps: true, overflow: true, lock_unwrap: true }
    }
}

/// The repo's rule → file scoping. `rel` is the path relative to
/// `src/`, with `/` separators (e.g. `service/http.rs`).
pub fn rules_for(rel: &str) -> RuleSet {
    RuleSet {
        // The shim itself is the one place raw std::sync belongs.
        sync: rel != "sync.rs",
        taps: matches!(
            rel,
            "net.rs"
                | "service/cluster.rs"
                | "service/http.rs"
                | "service/store.rs"
                | "coordinator/cache.rs"
                | "runtime/mod.rs"
        ),
        overflow: matches!(
            rel,
            "rational.rs" | "wide.rs" | "designspace/envelope.rs" | "designspace/extrema.rs"
        ),
        lock_unwrap: rel == "pool.rs"
            || rel == "net.rs"
            || rel.starts_with("service/")
            || rel.starts_with("pipeline/"),
    }
}

const WAIVER_KINDS: &[&str] = &["sync", "fault", "overflow", "lock"];

/// Waiver comments (`// lint: <kind>-ok(reason)`) by line. The reason
/// is mandatory: `overflow-ok()` does not waive.
pub struct Waivers {
    by_line: Vec<(usize, &'static str)>,
}

impl Waivers {
    pub fn scan(src: &str) -> Waivers {
        let mut by_line = Vec::new();
        for (i, text) in src.lines().enumerate() {
            let Some(at) = text.find("lint:") else { continue };
            let rest = &text[at..];
            for &kind in WAIVER_KINDS {
                let tag = format!("{kind}-ok(");
                if let Some(p) = rest.find(&tag) {
                    let reason = &rest[p + tag.len()..];
                    if !reason.trim_start().starts_with(')') && !reason.trim().is_empty() {
                        by_line.push((i + 1, kind));
                    }
                }
            }
        }
        Waivers { by_line }
    }

    /// A waiver covers its own line and the three lines below it.
    pub fn covers(&self, kind: &str, line: usize) -> bool {
        let lo = line.saturating_sub(3);
        self.by_line.iter().any(|&(l, k)| k == kind && l >= lo && l <= line)
    }
}

/// Everything a single-file pass produces.
#[derive(Default)]
pub struct FileOutcome {
    pub violations: Vec<Violation>,
    /// `faults::inject("site", ..)` literals found in non-test code.
    pub inject_sites: Vec<(String, usize)>,
    /// Entries of a `const SITES: &[&str]` registry, if this file has one.
    pub sites_registry: Vec<(String, usize)>,
    /// First-argument literals of `counter("…")`/`gauge("…")`/
    /// `histogram("…")` calls found in non-test code.
    pub metric_uses: Vec<(String, usize)>,
    /// Metric names declared in a `const METRICS` registry, if this
    /// file has one (constructor-call or `name:` struct-field form).
    pub metrics_registry: Vec<(String, usize)>,
}

/// Lint one file's source under `rules`. Fails only if syn cannot parse.
pub fn lint_file(rel: &str, src: &str, rules: RuleSet) -> Result<FileOutcome, syn::Error> {
    let ast = syn::parse_file(src)?;
    let waivers = Waivers::scan(src);
    let mut l = Linter {
        file: rel.to_string(),
        rules,
        waivers,
        fns: Vec::new(),
        out: FileOutcome::default(),
    };
    l.visit_file(&ast);
    Ok(l.out)
}

struct FnCtx {
    fn_line: usize,
    has_inject: bool,
    io_calls: Vec<(usize, String)>,
}

struct Linter {
    file: String,
    rules: RuleSet,
    waivers: Waivers,
    fns: Vec<FnCtx>,
    out: FileOutcome,
}

/// `std::sync` items that must come through `crate::sync` instead.
/// (`Arc`, `Weak`, `mpsc`, and the poison/result types stay allowed —
/// they are not lock primitives, so loom does not need to see them.)
const BANNED_SYNC: &[&str] = &[
    "Mutex",
    "RwLock",
    "Condvar",
    "Barrier",
    "Once",
    "OnceLock",
    "OnceState",
    "LazyLock",
    "MutexGuard",
    "RwLockReadGuard",
    "RwLockWriteGuard",
    "WaitTimeoutResult",
];

/// Method names that perform outbound I/O on a reader/writer/stream.
const IO_METHODS: &[&str] = &[
    "read_to_end",
    "read_exact",
    "read_to_string",
    "read_line",
    "write_all",
    "write_fmt",
    "sync_all",
    "sync_data",
];

/// `Qual::method` path calls that perform file/socket I/O.
fn io_path_call(segs: &[String]) -> Option<String> {
    let n = segs.len();
    if n < 2 {
        return None;
    }
    let hit = match (segs[n - 2].as_str(), segs[n - 1].as_str()) {
        ("fs", "read" | "write" | "read_to_string" | "rename" | "remove_file" | "copy") => true,
        ("File", "open" | "create") => true,
        ("TcpStream", "connect" | "connect_timeout") => true,
        _ => false,
    };
    hit.then(|| format!("{}::{}", segs[n - 2], segs[n - 1]))
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if !a.path().is_ident("cfg") {
            return false;
        }
        match &a.meta {
            syn::Meta::List(ml) => ml.tokens.to_string().contains("test"),
            _ => false,
        }
    })
}

fn is_test_fn(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| a.path().is_ident("test")) || is_cfg_test(attrs)
}

fn unparen(mut e: &syn::Expr) -> &syn::Expr {
    loop {
        match e {
            syn::Expr::Paren(p) => e = &p.expr,
            syn::Expr::Group(g) => e = &g.expr,
            _ => return e,
        }
    }
}

fn is_int_literal(e: &syn::Expr) -> bool {
    match unparen(e) {
        syn::Expr::Lit(l) => matches!(l.lit, syn::Lit::Int(_)),
        syn::Expr::Unary(u) => {
            matches!(u.op, syn::UnOp::Neg(_)) && is_int_literal(&u.expr)
        }
        _ => false,
    }
}

fn is_cast(e: &syn::Expr) -> bool {
    matches!(unparen(e), syn::Expr::Cast(_))
}

fn path_segs(p: &syn::Path) -> Vec<String> {
    p.segments.iter().map(|s| s.ident.to_string()).collect()
}

fn flatten_use(tree: &syn::UseTree, prefix: &mut Vec<String>, out: &mut Vec<(Vec<String>, usize)>) {
    match tree {
        syn::UseTree::Path(p) => {
            prefix.push(p.ident.to_string());
            flatten_use(&p.tree, prefix, out);
            prefix.pop();
        }
        syn::UseTree::Name(n) => {
            let mut full = prefix.clone();
            full.push(n.ident.to_string());
            out.push((full, n.span().start().line));
        }
        syn::UseTree::Rename(r) => {
            let mut full = prefix.clone();
            full.push(r.ident.to_string());
            out.push((full, r.span().start().line));
        }
        syn::UseTree::Glob(g) => {
            let mut full = prefix.clone();
            full.push("*".to_string());
            out.push((full, g.span().start().line));
        }
        syn::UseTree::Group(grp) => {
            for t in &grp.items {
                flatten_use(t, prefix, out);
            }
        }
    }
}

/// A `std::sync` path is banned when any segment past `sync` is a lock
/// primitive, anything atomic, or a glob that could pull one in.
fn banned_sync_path(segs: &[String]) -> bool {
    if segs.len() < 2 || segs[0] != "std" || segs[1] != "sync" {
        return false;
    }
    segs[2..].iter().any(|s| {
        s == "atomic" || s == "*" || s.starts_with("Atomic") || BANNED_SYNC.contains(&s.as_str())
    })
}

impl Linter {
    fn waived(&self, kind: &str, line: usize) -> bool {
        if self.waivers.covers(kind, line) {
            return true;
        }
        // fn-level waiver: a waiver just above the enclosing signature.
        self.fns.last().is_some_and(|f| self.waivers.covers(kind, f.fn_line))
    }

    fn push(&mut self, rule: &'static str, kind: &str, line: usize, msg: String) {
        if !self.waived(kind, line) {
            self.out.violations.push(Violation { file: self.file.clone(), line, rule, msg });
        }
    }

    fn enter_fn(&mut self, fn_line: usize) {
        self.fns.push(FnCtx { fn_line, has_inject: false, io_calls: Vec::new() });
    }

    fn leave_fn(&mut self) {
        let ctx = self.fns.pop().expect("balanced fn stack");
        if !self.rules.taps || ctx.has_inject {
            return;
        }
        for (line, what) in ctx.io_calls {
            // `waived` consults the *current* stack top, so re-check both
            // the call line and the just-popped fn's own line here.
            if self.waivers.covers("fault", line) || self.waivers.covers("fault", ctx.fn_line) {
                continue;
            }
            self.out.violations.push(Violation {
                file: self.file.clone(),
                line,
                rule: "fault-taps",
                msg: format!(
                    "`{what}` in a fault-boundary file, but the function never calls \
                     `faults::inject` (add a tap or a `// lint: fault-ok(reason)` waiver)"
                ),
            });
        }
    }

    fn record_io(&mut self, line: usize, what: String) {
        if let Some(ctx) = self.fns.last_mut() {
            ctx.io_calls.push((line, what));
        }
    }
}

impl<'ast> Visit<'ast> for Linter {
    fn visit_item_mod(&mut self, i: &'ast syn::ItemMod) {
        if is_cfg_test(&i.attrs) {
            return;
        }
        visit::visit_item_mod(self, i);
    }

    fn visit_item_fn(&mut self, i: &'ast syn::ItemFn) {
        if is_test_fn(&i.attrs) {
            return;
        }
        self.enter_fn(i.sig.fn_token.span().start().line);
        visit::visit_item_fn(self, i);
        self.leave_fn();
    }

    fn visit_impl_item_fn(&mut self, i: &'ast syn::ImplItemFn) {
        if is_test_fn(&i.attrs) {
            return;
        }
        self.enter_fn(i.sig.fn_token.span().start().line);
        visit::visit_impl_item_fn(self, i);
        self.leave_fn();
    }

    fn visit_item_use(&mut self, i: &'ast syn::ItemUse) {
        if self.rules.sync {
            let mut leaves = Vec::new();
            flatten_use(&i.tree, &mut Vec::new(), &mut leaves);
            for (segs, line) in leaves {
                if banned_sync_path(&segs) {
                    self.push(
                        "sync-imports",
                        "sync",
                        line,
                        format!(
                            "`{}` imported from std::sync — use `crate::sync` so loom \
                             models the primitive",
                            segs.join("::")
                        ),
                    );
                }
            }
        }
        visit::visit_item_use(self, i);
    }

    fn visit_path(&mut self, p: &'ast syn::Path) {
        if self.rules.sync {
            let segs = path_segs(p);
            if banned_sync_path(&segs) {
                self.push(
                    "sync-imports",
                    "sync",
                    p.span().start().line,
                    format!(
                        "qualified `{}` — use `crate::sync` so loom models the primitive",
                        segs.join("::")
                    ),
                );
            }
        }
        visit::visit_path(self, p);
    }

    fn visit_item_const(&mut self, i: &'ast syn::ItemConst) {
        if i.ident == "SITES" {
            struct Strings(Vec<(String, usize)>);
            impl<'a> Visit<'a> for Strings {
                fn visit_lit_str(&mut self, l: &'a syn::LitStr) {
                    self.0.push((l.value(), l.span().start().line));
                }
            }
            let mut s = Strings(Vec::new());
            s.visit_expr(&i.expr);
            self.out.sites_registry.extend(s.0);
        }
        if i.ident == "METRICS" {
            // Each registry entry is a constructor call whose first
            // argument is the metric name (`c("pool.donations", …)`) or
            // a `Spec { name: "…", … }` literal; help strings and bucket
            // tables are deliberately not collected.
            struct Names(Vec<(String, usize)>);
            impl<'a> Visit<'a> for Names {
                fn visit_expr_call(&mut self, c: &'a syn::ExprCall) {
                    if let Some(syn::Expr::Lit(l)) = c.args.first().map(unparen) {
                        if let syn::Lit::Str(s) = &l.lit {
                            self.0.push((s.value(), s.span().start().line));
                        }
                    }
                    visit::visit_expr_call(self, c);
                }
                fn visit_expr_struct(&mut self, e: &'a syn::ExprStruct) {
                    for f in &e.fields {
                        if matches!(&f.member, syn::Member::Named(id) if id == "name") {
                            if let syn::Expr::Lit(l) = unparen(&f.expr) {
                                if let syn::Lit::Str(s) = &l.lit {
                                    self.0.push((s.value(), s.span().start().line));
                                }
                            }
                        }
                    }
                    visit::visit_expr_struct(self, e);
                }
            }
            let mut n = Names(Vec::new());
            n.visit_expr(&i.expr);
            self.out.metrics_registry.extend(n.0);
        }
        visit::visit_item_const(self, i);
    }

    fn visit_expr_binary(&mut self, b: &'ast syn::ExprBinary) {
        if self.rules.overflow {
            let op = match b.op {
                syn::BinOp::Mul(_) => Some("*"),
                syn::BinOp::Add(_) => Some("+"),
                syn::BinOp::Shl(_) => Some("<<"),
                _ => None,
            };
            if let Some(op) = op {
                let exempt = is_int_literal(&b.left)
                    || is_int_literal(&b.right)
                    || (is_cast(&b.left) && is_cast(&b.right));
                if !exempt {
                    self.push(
                        "overflow",
                        "overflow",
                        b.span().start().line,
                        format!(
                            "unchecked `{op}` in exact-arithmetic code — use the \
                             `checked_`/widening counterpart or waive with \
                             `// lint: overflow-ok(reason)`"
                        ),
                    );
                }
            }
        }
        visit::visit_expr_binary(self, b);
    }

    fn visit_expr_method_call(&mut self, m: &'ast syn::ExprMethodCall) {
        let name = m.method.to_string();
        if self.rules.lock_unwrap && name == "unwrap" {
            if let syn::Expr::MethodCall(inner) = unparen(&m.receiver) {
                let im = inner.method.to_string();
                if matches!(im.as_str(), "lock" | "try_lock" | "wait" | "wait_timeout" | "wait_while")
                {
                    self.push(
                        "lock-unwrap",
                        "lock",
                        m.span().start().line,
                        format!(
                            "`.{im}().unwrap()` cascades lock poison — use \
                             `sync::plock`/`sync::cwait` (poison means a task panic \
                             that was already caught)"
                        ),
                    );
                }
            }
        }
        if self.rules.taps && IO_METHODS.contains(&name.as_str()) {
            self.record_io(m.span().start().line, format!(".{name}()"));
        }
        visit::visit_expr_method_call(self, m);
    }

    fn visit_expr_call(&mut self, c: &'ast syn::ExprCall) {
        if let syn::Expr::Path(p) = unparen(&c.func) {
            let segs = path_segs(&p.path);
            if segs.last().is_some_and(|s| s == "inject") {
                if let Some(ctx) = self.fns.last_mut() {
                    ctx.has_inject = true;
                }
                if let Some(syn::Expr::Lit(l)) = c.args.first().map(unparen) {
                    if let syn::Lit::Str(s) = &l.lit {
                        self.out.inject_sites.push((s.value(), s.span().start().line));
                    }
                }
            }
            if matches!(segs.last().map(String::as_str), Some("counter" | "gauge" | "histogram"))
            {
                if let Some(syn::Expr::Lit(l)) = c.args.first().map(unparen) {
                    if let syn::Lit::Str(s) = &l.lit {
                        self.out.metric_uses.push((s.value(), s.span().start().line));
                    }
                }
            }
            if self.rules.taps {
                if let Some(what) = io_path_call(&segs) {
                    self.record_io(c.span().start().line, what);
                }
            }
        }
        visit::visit_expr_call(self, c);
    }
}

/// The whole-tree report.
pub struct Report {
    pub files: usize,
    pub violations: Vec<Violation>,
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root` with the repo scoping, then
/// cross-check injection-site literals against the `SITES` registry in
/// both directions.
pub fn run(src_root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    walk(src_root, &mut files)?;
    files.sort();
    let mut violations = Vec::new();
    let mut used: Vec<(String, String, usize)> = Vec::new();
    let mut registry: Vec<(String, String, usize)> = Vec::new();
    let mut metric_used: Vec<(String, String, usize)> = Vec::new();
    let mut metric_reg: Vec<(String, String, usize)> = Vec::new();
    let nfiles = files.len();
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .expect("walked under root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&path).map_err(|e| format!("{rel}: {e}"))?;
        match lint_file(&rel, &src, rules_for(&rel)) {
            Ok(outcome) => {
                violations.extend(outcome.violations);
                used.extend(outcome.inject_sites.into_iter().map(|(s, l)| (rel.clone(), s, l)));
                registry
                    .extend(outcome.sites_registry.into_iter().map(|(s, l)| (rel.clone(), s, l)));
                metric_used
                    .extend(outcome.metric_uses.into_iter().map(|(s, l)| (rel.clone(), s, l)));
                metric_reg.extend(
                    outcome.metrics_registry.into_iter().map(|(s, l)| (rel.clone(), s, l)),
                );
            }
            Err(e) => violations.push(Violation {
                file: rel,
                line: e.span().start().line,
                rule: "parse",
                msg: e.to_string(),
            }),
        }
    }
    let reg_names: BTreeSet<&str> = registry.iter().map(|(_, s, _)| s.as_str()).collect();
    let used_names: BTreeSet<&str> = used.iter().map(|(_, s, _)| s.as_str()).collect();
    for (file, site, line) in &used {
        if !reg_names.contains(site.as_str()) {
            violations.push(Violation {
                file: file.clone(),
                line: *line,
                rule: "fault-taps",
                msg: format!("injection site \"{site}\" is not in `faults::SITES`"),
            });
        }
    }
    for (file, site, line) in &registry {
        if !used_names.contains(site.as_str()) {
            violations.push(Violation {
                file: file.clone(),
                line: *line,
                rule: "fault-taps",
                msg: format!("`faults::SITES` entry \"{site}\" has no `faults::inject` call site"),
            });
        }
    }
    // Same two-way discipline for the metrics registry: a handle built
    // on an unregistered name would be a compile error anyway (const
    // eval panics), but the lint reports it with a message; a registered
    // metric nothing records renders as a forever-zero lie on /metrics.
    let metric_reg_names: BTreeSet<&str> = metric_reg.iter().map(|(_, s, _)| s.as_str()).collect();
    let metric_used_names: BTreeSet<&str> =
        metric_used.iter().map(|(_, s, _)| s.as_str()).collect();
    for (file, name, line) in &metric_used {
        if !metric_reg_names.contains(name.as_str()) {
            violations.push(Violation {
                file: file.clone(),
                line: *line,
                rule: "obs-registry",
                msg: format!("metric \"{name}\" is recorded but not registered in `METRICS`"),
            });
        }
    }
    for (file, name, line) in &metric_reg {
        if !metric_used_names.contains(name.as_str()) {
            violations.push(Violation {
                file: file.clone(),
                line: *line,
                rule: "obs-registry",
                msg: format!("`METRICS` entry \"{name}\" is never recorded (dead metric)"),
            });
        }
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report { files: nfiles, violations })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiver_needs_a_reason() {
        let w = Waivers::scan("// lint: overflow-ok()\nlet x = 1;\n// lint: overflow-ok(bounded)\n");
        assert!(!w.covers("overflow", 1), "empty reason must not waive");
        assert!(w.covers("overflow", 3));
        assert!(w.covers("overflow", 6), "covers three lines below");
        assert!(!w.covers("overflow", 7), "but not four");
        assert!(!w.covers("sync", 3), "kinds do not cross");
    }

    #[test]
    fn banned_paths() {
        let p = |s: &str| s.split("::").map(str::to_string).collect::<Vec<_>>();
        assert!(banned_sync_path(&p("std::sync::Mutex")));
        assert!(banned_sync_path(&p("std::sync::atomic::AtomicU64::new")));
        assert!(banned_sync_path(&p("std::sync::*")));
        assert!(!banned_sync_path(&p("std::sync::Arc")));
        assert!(!banned_sync_path(&p("std::sync::mpsc::channel")));
        assert!(!banned_sync_path(&p("crate::sync::Mutex")));
    }
}
