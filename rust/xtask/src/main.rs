//! `cargo xtask lint` — run the polygen-lint suite over `../src`.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_default();
    if cmd != "lint" {
        eprintln!("usage: cargo xtask lint [--root <src-dir>]");
        return ExitCode::from(2);
    }
    let mut root =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask sits in rust/").join("src");
    while let Some(flag) = args.next() {
        match flag.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown flag {other}");
                return ExitCode::from(2);
            }
        }
    }
    match xtask::run(&root) {
        Ok(report) => {
            for v in &report.violations {
                println!("{v}");
            }
            println!(
                "polygen-lint: {} files, {} violation{}",
                report.files,
                report.violations.len(),
                if report.violations.len() == 1 { "" } else { "s" }
            );
            if report.violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("polygen-lint: {e}");
            ExitCode::FAILURE
        }
    }
}
