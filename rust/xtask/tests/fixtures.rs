//! Self-test: each rule fires on its fixture's `// FLAG` lines — and
//! only those, which also proves the waiver forms (trailing, above the
//! line, above the `fn`) suppress findings.

use std::path::Path;

use xtask::{lint_file, RuleSet};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn flag_lines(src: &str) -> Vec<usize> {
    src.lines()
        .enumerate()
        .filter(|(_, l)| l.trim_end().ends_with("// FLAG"))
        .map(|(i, _)| i + 1)
        .collect()
}

fn check(name: &str, rules: RuleSet, rule: &str) {
    let src = fixture(name);
    let out = lint_file(name, &src, rules).unwrap();
    let mut got: Vec<usize> = out.violations.iter().map(|v| v.line).collect();
    got.sort_unstable();
    assert_eq!(got, flag_lines(&src), "{name} violations: {:#?}", out.violations);
    for v in &out.violations {
        assert_eq!(v.rule, rule, "{v}");
    }
}

#[test]
fn sync_imports_fixture() {
    check("sync_imports.rs", RuleSet { sync: true, ..Default::default() }, "sync-imports");
}

#[test]
fn fault_taps_fixture() {
    check("fault_taps.rs", RuleSet { taps: true, ..Default::default() }, "fault-taps");
}

#[test]
fn overflow_fixture() {
    check("overflow.rs", RuleSet { overflow: true, ..Default::default() }, "overflow");
}

#[test]
fn lock_unwrap_fixture() {
    check("lock_unwrap.rs", RuleSet { lock_unwrap: true, ..Default::default() }, "lock-unwrap");
}

#[test]
fn site_literals_are_collected_both_ways() {
    let src = r#"
pub const SITES: &[&str] = &["a.site", "b.site"];
fn f() {
    let _ = faults::inject("a.site", &[]);
    let _ = faults::inject("c.site", &[]);
}
"#;
    let out = lint_file("faults.rs", src, RuleSet::default()).unwrap();
    let reg: Vec<&str> = out.sites_registry.iter().map(|(s, _)| s.as_str()).collect();
    let used: Vec<&str> = out.inject_sites.iter().map(|(s, _)| s.as_str()).collect();
    assert_eq!(reg, ["a.site", "b.site"]);
    assert_eq!(used, ["a.site", "c.site"]);
}

#[test]
fn metric_literals_are_collected_both_ways() {
    let src = fixture("obs_registry.rs");
    let out = lint_file("obs_registry.rs", &src, RuleSet::default()).unwrap();
    assert!(out.violations.is_empty(), "{:#?}", out.violations);
    let reg: Vec<&str> = out.metrics_registry.iter().map(|(s, _)| s.as_str()).collect();
    let used: Vec<&str> = out.metric_uses.iter().map(|(s, _)| s.as_str()).collect();
    assert_eq!(reg, ["pool.donations", "pool.queue_depth", "net.call_ms", "struct.literal"]);
    assert_eq!(
        used,
        ["pool.donations", "pool.queue_depth", "net.call_ms"],
        "help strings, bucket tables, and #[cfg(test)] uses must not be collected"
    );
}

#[test]
fn obs_registry_cross_check_fails_both_ways() {
    // `run` walks a tree: give it one declaring a dead metric and
    // recording an unregistered one — both directions must fail, and
    // the matched name must stay silent.
    let dir = std::env::temp_dir().join(format!("xtask_obs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("metrics.rs"),
        "pub const METRICS: &[Spec] = &[\n\
         \tc(\"live.metric\", \"recorded below\"),\n\
         \tg(\"dead.metric\", \"nothing records this\"),\n\
         ];\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("user.rs"),
        "const LIVE: Counter = counter(\"live.metric\");\n\
         const GHOST: Counter = counter(\"ghost.metric\");\n",
    )
    .unwrap();
    let report = xtask::run(&dir).unwrap();
    let obs: Vec<String> = report
        .violations
        .iter()
        .filter(|v| v.rule == "obs-registry")
        .map(|v| v.to_string())
        .collect();
    assert_eq!(obs.len(), 2, "{obs:#?}");
    assert!(obs.iter().any(|m| m.contains("\"dead.metric\" is never recorded")), "{obs:#?}");
    assert!(
        obs.iter().any(|m| m.contains("\"ghost.metric\" is recorded but not registered")),
        "{obs:#?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repo_scoping_matches_design() {
    assert!(!xtask::rules_for("sync.rs").sync, "the shim may use std::sync");
    assert!(xtask::rules_for("pool.rs").sync);
    assert!(xtask::rules_for("service/store.rs").taps);
    assert!(!xtask::rules_for("dse/mod.rs").taps);
    assert!(xtask::rules_for("designspace/extrema.rs").overflow);
    assert!(!xtask::rules_for("designspace/region.rs").overflow);
    assert!(xtask::rules_for("service/exec.rs").lock_unwrap);
    assert!(!xtask::rules_for("rational.rs").lock_unwrap);
}
