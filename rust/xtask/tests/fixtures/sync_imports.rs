//! polygen-lint fixture: `sync-imports` rule. Lines marked `// FLAG`
//! must fire; everything else must stay silent.

use std::sync::Mutex; // FLAG
use std::sync::{Arc, Condvar}; // FLAG
use std::sync::atomic::AtomicU64; // FLAG
use std::sync::mpsc::channel;
use crate::sync::Mutex as Shim;

// lint: sync-ok(const-init static in never-modeled fixture code)
use std::sync::OnceLock;

fn qualified() {
    let _ = std::sync::Mutex::new(0); // FLAG
}

// lint: sync-ok(fixture fn-level waiver covers the signature too)
fn waived_fn() -> std::sync::MutexGuard<'static, ()> {
    unimplemented!()
}

#[cfg(test)]
mod tests {
    use std::sync::Barrier;
}
