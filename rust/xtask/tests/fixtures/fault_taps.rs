//! polygen-lint fixture: `fault-taps` rule. Lines marked `// FLAG`
//! must fire; everything else must stay silent.

fn untapped_read(path: &std::path::Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_default() // FLAG
}

fn tapped_read(path: &std::path::Path) -> Vec<u8> {
    let _ = faults::inject("cache.load", &[]);
    std::fs::read(path).unwrap_or_default()
}

// lint: fault-ok(fixture: covered by the save-side tap)
fn waived_fn(path: &std::path::Path) {
    let _ = std::fs::rename(path, path);
}

fn waived_line(path: &std::path::Path) {
    // lint: fault-ok(fixture: setup write, not a fault boundary)
    let _ = std::fs::write(path, b"x");
}

fn method_io(mut s: impl std::io::Write) {
    let _ = s.write_all(b"hi"); // FLAG
}
