//! Fixture for the `obs-registry` collection pass: names inside a
//! `const METRICS` registry (constructor-call and struct-field forms)
//! and the first arguments of `counter`/`gauge`/`histogram` calls are
//! both collected; help strings and bucket tables are not. The two-way
//! cross-check itself runs in `xtask::run`.

pub const METRICS: &[Spec] = &[
    c("pool.donations", "counter help text, never collected as a name"),
    g("pool.queue_depth", "gauge help"),
    h("net.call_ms", "histogram help", MS_CALL),
    Spec { name: "struct.literal", kind: Kind::Counter, help: "struct form", buckets: NO_BUCKETS },
];

const DONATIONS: Counter = counter("pool.donations");
const DEPTH: Gauge = gauge("pool.queue_depth");

fn observe_call(ms: u64) {
    histogram("net.call_ms").observe(ms);
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_uses_are_not_collected() {
        let _ = counter("test.only.metric");
    }
}
