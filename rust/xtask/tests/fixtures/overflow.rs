//! polygen-lint fixture: `overflow` rule. Lines marked `// FLAG` must
//! fire; everything else must stay silent.

fn raw_ops(a: i64, b: i64) -> i64 {
    let p = a * b; // FLAG
    let s = a + b; // FLAG
    let h = a << b; // FLAG
    p - s - h
}

fn sanctioned(a: i64, b: i64) -> i128 {
    let wide = (a as i128) * (b as i128);
    let lit = 2 * a;
    let shift = 1i64 << b;
    let checked = a.checked_add(b).unwrap_or(lit).checked_mul(shift).unwrap_or(0);
    wide.checked_add(checked as i128).unwrap_or(0)
}

fn waived_line(a: i64, b: i64) -> i64 {
    a * b // lint: overflow-ok(fixture: bounded by construction)
}

// lint: overflow-ok(fixture: fn-level waiver covers the whole body)
fn waived_fn(a: i64, b: i64) -> i64 {
    let p = a * b;
    let q = a + p;
    q << 1
}

#[cfg(test)]
mod tests {
    fn helper(a: i64, b: i64) -> i64 {
        a * b
    }
}
