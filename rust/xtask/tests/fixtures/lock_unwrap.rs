//! polygen-lint fixture: `lock-unwrap` rule. Lines marked `// FLAG`
//! must fire; everything else must stay silent.

fn bad(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap() // FLAG
}

fn bad_wait(cv: &std::sync::Condvar, m: &std::sync::Mutex<bool>) {
    let g = m.lock().unwrap(); // FLAG
    let _g = cv.wait(g).unwrap(); // FLAG
}

fn good(m: &std::sync::Mutex<u32>) -> u32 {
    *crate::sync::plock(m)
}

fn waived(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap() // lint: lock-ok(fixture: single-threaded setup path)
}

fn not_a_lock(r: Result<u32, ()>) -> u32 {
    r.unwrap()
}
